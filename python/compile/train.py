"""BNN training (STE) on ShapeSet-10 + BKW2 weight export.

Build-time only.  Trains the width-scaled BNN of model.py with the
straight-through estimator (sign forward / Htanh-clip backward — the
paper's Sec. 4.2 recipe), a hand-rolled Adam (no optax offline), and
running BatchNorm statistics folded to per-channel affines at export.

BKW2 binary format (mirrored by rust/src/model/format.rs — the rust
side reads BKW1 and BKW2; this exporter writes BKW2 so the file
carries its own architecture):
    magic  b"BKW2"
    u32le  input_c, input_h, input_w, classes
    u32le  n_ops
    n_ops * { u8 opcode, fields }
        0 = conv2d:   u32le cout, ksize, stride, pad; u8 binarized
        1 = maxpool2
        2 = batchnorm
        3 = sign
        4 = flatten
        5 = linear:   u32le dout; u8 binarized
        6 = scheme:   u32le scheme code (SCHEMES; emitted first, only
                      for non-default schemes — default-scheme files
                      stay byte-identical to pre-scheme ones)
    u32le  n_tensors
    n_tensors * {
        u16le name_len, name (utf-8),
        u8 dtype (0 = f32, 1 = u32),
        u8 ndim, ndim * u32le dims,
        data (little-endian, row-major)
    }
    optional trailing labels section:
        magic  b"LBLS"
        u32le  n_labels (one per class, in class order)
        n_labels * { u16le len, utf-8 bytes }
(BKW1 is the same without the spec section.)  Exported tensor names:
meta.widths (u32 [c1..c6, f1, f2, 10], kept for BKW1-era tooling),
conv1.w .. conv6.w, fc1.w .. fc3.w (sign-binarized {-1,+1} f32) and
bn_conv1.a/.b .. bn_fc3.a/.b (folded BN affine, f32).  The labels
section carries the ShapeSet-10 class names so the serving stack can
answer with human-readable labels; readers that stop after the tensor
section skip it for free, and label-less files serve with numeric
labels.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model

DTYPE_F32 = 0
DTYPE_U32 = 1


# ---------------------------------------------------------------------------
# hand-rolled Adam (pytree)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    def step(p, m_, v_):
        mhat = m_ / (1 - b1 ** tf)
        vhat = v_ / (1 - b2 ** tf)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)
    new_params = jax.tree_util.tree_map(step, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def clip_latents(tp):
    """Courbariaux: clip latent weights to [-1, 1] after each update."""
    return jax.tree_util.tree_map(
        lambda x: jnp.clip(x, -1.0, 1.0) if x.ndim > 1 else x, tp)


# ---------------------------------------------------------------------------
# loss / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def make_train_step(cfg: model.ModelConfig, lr: float):
    def loss_fn(tp, x, y):
        logits, stats = model.apply_train(cfg, tp, x)
        return cross_entropy(logits, y), (logits, stats)

    @jax.jit
    def step(tp, opt, x, y):
        (loss, (logits, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(tp, x, y)
        tp, opt = adam_update(tp, grads, opt, lr=lr)
        tp = clip_latents(tp)
        acc = (logits.argmax(axis=1) == y).mean()
        return tp, opt, loss, acc, stats
    return step


def update_running(running: Dict[str, Any], stats: Dict[str, Any],
                   momentum: float = 0.9) -> Dict[str, Any]:
    out = {}
    for k, (mu, var) in stats.items():
        if k in running:
            rmu, rvar = running[k]
            out[k] = (momentum * rmu + (1 - momentum) * mu,
                      momentum * rvar + (1 - momentum) * var)
        else:
            out[k] = (mu, var)
    return out


def train(cfg: model.ModelConfig, steps: int = 300, batch: int = 64,
          lr: float = 2e-3, seed: int = 0, train_n: int = 4096,
          log_every: int = 50, log=print) -> Tuple[Dict, Dict, list]:
    """Train on ShapeSet-10; returns (train_pytree, running_stats, history)."""
    imgs, labels = dataset.make_split(train_n, seed=seed + 1)
    x_all = jnp.asarray(dataset.normalize(imgs))
    y_all = jnp.asarray(labels.astype(np.int32))

    tp = model.init_train_params(cfg, seed=seed)
    opt = adam_init(tp)
    step_fn = make_train_step(cfg, lr)
    running: Dict[str, Any] = {}
    history = []
    rng = np.random.default_rng(seed + 2)
    for i in range(steps):
        idx = rng.integers(0, train_n, size=batch)
        tp, opt, loss, acc, stats = step_fn(tp, opt, x_all[idx], y_all[idx])
        running = update_running(running, stats)
        history.append((i, float(loss), float(acc)))
        if log_every and (i % log_every == 0 or i == steps - 1):
            log(f"step {i:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}")
    return tp, running, history


def eval_accuracy(cfg: model.ModelConfig, params: Dict[str, Any],
                  imgs: np.ndarray, labels: np.ndarray,
                  variant: str = "optimized", batch: int = 64) -> float:
    """Inference-graph accuracy on uint8 HWC images (the folded model)."""
    x = dataset.normalize(imgs)
    n = x.shape[0]
    correct = 0
    fn = jax.jit(model.make_inference_fn(cfg, variant))
    for i in range(0, n - n % batch, batch):
        logits = fn(params, jnp.asarray(x[i:i + batch]))
        correct += int((np.asarray(logits).argmax(1)
                        == labels[i:i + batch]).sum())
    return correct / (n - n % batch)


# ---------------------------------------------------------------------------
# BKW1 export
# ---------------------------------------------------------------------------

def _write_tensor(f, name: str, arr: np.ndarray) -> None:
    data = np.ascontiguousarray(arr)
    if data.dtype == np.float32:
        dt = DTYPE_F32
    elif data.dtype == np.uint32:
        dt = DTYPE_U32
    else:
        raise TypeError(data.dtype)
    nb = name.encode("utf-8")
    f.write(struct.pack("<H", len(nb)))
    f.write(nb)
    f.write(struct.pack("<BB", dt, data.ndim))
    for d in data.shape:
        f.write(struct.pack("<I", d))
    f.write(data.tobytes())


# NetSpec opcodes (BKW2 spec section; mirror of rust model/spec.rs).
OP_CONV2D = 0
OP_MAXPOOL2 = 1
OP_BATCHNORM = 2
OP_SIGN = 3
OP_FLATTEN = 4
OP_LINEAR = 5
OP_SCHEME = 6

# Quantization-scheme wire codes (mirror of QuantScheme::wire_byte).
SCHEMES = {
    "sign_sign": 0,
    "xnor_alpha": 1,
    "binary_weight": 2,
    "ternary_weight": 3,
}
DEFAULT_SCHEME = "sign_sign"


def spec_ops(cfg: model.ModelConfig,
             scheme: str = DEFAULT_SCHEME) -> list:
    """ModelConfig -> the canonical NetSpec op list of the rust IR:
    [Sign]? Conv2d [MaxPool2] BatchNorm per conv, Flatten, then
    [Sign] Linear BatchNorm per fc (all fcs are binarized).  Under
    binary_weight (real activations) the grammar inverts: no Sign ops
    anywhere — only the weights are binarized."""
    signs = scheme != "binary_weight"
    ops: list = []
    for s in cfg.conv_specs:
        if s.binarized and signs:
            ops.append((OP_SIGN,))
        ops.append((OP_CONV2D, s.cout, s.ksize, s.stride, s.pad,
                    1 if s.binarized else 0))
        if s.pool:
            ops.append((OP_MAXPOOL2,))
        ops.append((OP_BATCHNORM,))
    ops.append((OP_FLATTEN,))
    for s in cfg.fc_specs:
        if signs:
            ops.append((OP_SIGN,))
        ops.append((OP_LINEAR, s.dout, 1))
        ops.append((OP_BATCHNORM,))
    return ops


def _write_spec(f, cfg: model.ModelConfig,
                scheme: str = DEFAULT_SCHEME) -> None:
    code = SCHEMES[scheme]
    ops = spec_ops(cfg, scheme)
    extra = 0 if code == 0 else 1
    f.write(struct.pack("<5I", model.IMAGE_C, model.IMAGE_HW,
                        model.IMAGE_HW, model.NUM_CLASSES,
                        len(ops) + extra))
    if extra:
        f.write(struct.pack("<BI", OP_SCHEME, code))
    for op in ops:
        f.write(struct.pack("<B", op[0]))
        if op[0] == OP_CONV2D:
            f.write(struct.pack("<4IB", *op[1:]))
        elif op[0] == OP_LINEAR:
            f.write(struct.pack("<IB", *op[1:]))


LABELS_MAGIC = b"LBLS"


def _write_labels(f, labels) -> None:
    f.write(LABELS_MAGIC)
    f.write(struct.pack("<I", len(labels)))
    for label in labels:
        lb = label.encode("utf-8")
        f.write(struct.pack("<H", len(lb)))
        f.write(lb)


def save_bkw(path: str, cfg: model.ModelConfig,
             params: Dict[str, Any], labels=None,
             scheme: str = DEFAULT_SCHEME) -> None:
    """Export the inference float pytree (binarize_params/fold_bn output,
    or alpha_params / ternarize_params for the non-default schemes) as
    BKW2: the NetSpec rides in the file, followed by the tensors and a
    trailing labels section.  `labels` defaults to the ShapeSet-10
    class names; pass a per-class list for other datasets, or [] to
    write a label-less file (numeric labels at serve time).  Layers
    whose pytree entry carries an "alpha" (alpha_params output) export
    it as `<layer>.alpha`; the xnor_alpha scheme requires one per
    binarized layer."""
    if labels is None:
        labels = dataset.CLASS_NAMES
    if labels and len(labels) != model.NUM_CLASSES:
        raise ValueError(
            f"{len(labels)} labels for {model.NUM_CLASSES} classes")
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme '{scheme}' "
                         f"(one of {sorted(SCHEMES)})")
    tensors: list[tuple[str, np.ndarray]] = []
    widths = np.asarray(cfg.widths + cfg.fc_widths, np.uint32)
    tensors.append(("meta.widths", widths))
    for s in list(cfg.conv_specs) + list(cfg.fc_specs):
        tensors.append((f"{s.name}.w", np.asarray(params[s.name]["w"])))
        if "alpha" in params[s.name]:
            tensors.append((f"{s.name}.alpha",
                            np.asarray(params[s.name]["alpha"])))
        tensors.append((f"bn_{s.name}.a",
                        np.asarray(params[f"bn_{s.name}"]["a"])))
        tensors.append((f"bn_{s.name}.b",
                        np.asarray(params[f"bn_{s.name}"]["b"])))
    with open(path, "wb") as f:
        f.write(b"BKW2")
        _write_spec(f, cfg, scheme)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            _write_tensor(f, name, arr)
        if labels:
            _write_labels(f, labels)


def _skip_spec(f) -> None:
    """Consume a BKW2 spec section (load_bkw returns tensors only)."""
    _c, _h, _w, _classes, n_ops = struct.unpack("<5I", f.read(20))
    for _ in range(n_ops):
        (opcode,) = struct.unpack("<B", f.read(1))
        if opcode == OP_CONV2D:
            f.read(17)  # 4 u32 + u8
        elif opcode == OP_LINEAR:
            f.read(5)   # u32 + u8
        elif opcode == OP_SCHEME:
            f.read(4)   # u32 scheme code
        elif opcode not in (OP_MAXPOOL2, OP_BATCHNORM, OP_SIGN,
                            OP_FLATTEN):
            raise ValueError(f"unknown opcode {opcode}")


def _iter_tensor_records(f):
    """Walk an open BKW stream: consume the magic (+ spec section) and
    yield one (name, dtype_byte, dims, data_bytes) per tensor record,
    leaving the stream positioned at the optional labels section.  The
    single copy of the record-walking arithmetic, shared by load_bkw
    and load_bkw_labels."""
    magic = f.read(4)
    assert magic in (b"BKW1", b"BKW2"), magic
    if magic == b"BKW2":
        _skip_spec(f)
    (n,) = struct.unpack("<I", f.read(4))
    for _ in range(n):
        (ln,) = struct.unpack("<H", f.read(2))
        name = f.read(ln).decode("utf-8")
        dt, ndim = struct.unpack("<BB", f.read(2))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        count = int(np.prod(dims)) if ndim else 1
        yield name, dt, dims, f.read(count * 4)


def load_bkw(path: str) -> Dict[str, np.ndarray]:
    """Read BKW1 or BKW2 back as {name: array} (tests / aot prep).
    Stops after the tensor section — a trailing labels section is
    skipped for free; use load_bkw_labels for it."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        for name, dt, dims, data in _iter_tensor_records(f):
            dtype = np.float32 if dt == DTYPE_F32 else np.uint32
            out[name] = np.frombuffer(data, dtype).reshape(dims).copy()
    return out


def load_bkw_labels(path: str):
    """The class-label table of a BKW file, or None when it carries
    none (mirror of the rust reader's labels())."""
    with open(path, "rb") as f:
        for _record in _iter_tensor_records(f):
            pass
        magic = f.read(4)
        if not magic:
            return None
        assert magic == LABELS_MAGIC, magic
        (n,) = struct.unpack("<I", f.read(4))
        labels = []
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            labels.append(f.read(ln).decode("utf-8"))
        return labels


def load_bkw_scheme(path: str) -> str:
    """The quantization-scheme name a BKW file declares (sign_sign for
    BKW1 files and scheme-less BKW2 files — mirror of the rust
    reader's default)."""
    names = {v: k for k, v in SCHEMES.items()}
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic in (b"BKW1", b"BKW2"), magic
        if magic == b"BKW1":
            return DEFAULT_SCHEME
        _c, _h, _w, _classes, n_ops = struct.unpack("<5I", f.read(20))
        for _ in range(n_ops):
            (opcode,) = struct.unpack("<B", f.read(1))
            if opcode == OP_SCHEME:
                (code,) = struct.unpack("<I", f.read(4))
                return names[code]
            if opcode == OP_CONV2D:
                f.read(17)
            elif opcode == OP_LINEAR:
                f.read(5)
        return DEFAULT_SCHEME


def bkw_to_pytree(cfg: model.ModelConfig,
                  raw: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """{name: array} -> the inference float pytree of model.py."""
    params: Dict[str, Any] = {}
    for s in list(cfg.conv_specs) + list(cfg.fc_specs):
        params[s.name] = {"w": jnp.asarray(raw[f"{s.name}.w"])}
        params[f"bn_{s.name}"] = {
            "a": jnp.asarray(raw[f"bn_{s.name}.a"]),
            "b": jnp.asarray(raw[f"bn_{s.name}.b"]),
        }
    return params
