"""AOT pipeline: dataset -> training -> HLO-text artifacts + manifest.

Emits everything the rust layer needs into artifacts/:

  dataset_test.bin / dataset_train.bin   BKD1 ShapeSet-10 splits
  weights_small.bkw                      trained  BNN (scale 0.25)
  weights_full.bkw                       random-init BNN (scale 1.0; Table-2
                                         timing does not need trained weights)
  bnn_<scale>_<variant>_b<batch>.hlo.txt whole-model inference executables
  k_<kernel>_<layer>.hlo.txt             kernel-level micro executables
  manifest.json                          input arg order/shapes/transforms
  train_log.txt                          loss curve of the build-time training

HLO *text* is the interchange format — jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly.  Weights are runtime ARGUMENTS, not baked constants, so one HLO
serves any checkpoint and the text stays small.

Run via `make artifacts`; idempotent at the Makefile level (stamp deps).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dataset, model, train

SCALES = {"small": 0.25, "full": 1.0}
BATCHES = {"small": (1, 8, 32), "full": (1, 8)}
TEST_N = 10_000   # matches the CIFAR-10 test split the paper times
TRAIN_N = 4_096
TRAIN_STEPS = 400
TRAIN_BATCH = 64
TRAIN_LR = 3e-3

# Kernel micro-bench shapes: (tag, D, K, N) — real gemm shapes of the
# full-scale BNN at batch 1 (conv) / batch 8 (fc1).
KERNEL_SHAPES = [
    ("conv2", 128, 1152, 1024),
    ("conv4", 256, 2304, 256),
    ("conv6", 512, 4608, 64),
    ("fc1b8", 1024, 8192, 8),
]


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text (return_tuple=True; see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# manifest input descriptors
# ---------------------------------------------------------------------------

def _dtype_tag(x) -> str:
    return {jnp.float32.dtype: "f32", jnp.uint32.dtype: "u32"}[x.dtype]


def input_descriptors(cfg: model.ModelConfig, params, x) -> list:
    """Describe every flattened HLO parameter of fn(params, x), in order.

    Each descriptor tells rust how to build the argument literal from the
    BKW1 weight file:
      transform "none"       -> load tensor `source` as-is
      transform "pack_rows"  -> reshape [D, ...] -> [D, K], sign, bit-pack
      kind "image"           -> the request batch (not from the bkw)
    """
    logical_k = {s.name: s.k for s in cfg.conv_specs}
    logical_k.update({s.name: s.din for s in cfg.fc_specs})

    leaves = jax.tree_util.tree_flatten_with_path((params, x))[0]
    descs = []
    for path, leaf in leaves:
        idx = path[0].idx
        if idx == 1:  # the image input
            descs.append({"name": "x", "kind": "image",
                          "dtype": _dtype_tag(leaf),
                          "shape": list(leaf.shape), "transform": "none",
                          "source": None})
            continue
        layer = path[1].key
        field = path[2].key
        if field == "wp":
            descs.append({"name": f"{layer}.wp", "kind": "weight",
                          "dtype": "u32", "shape": list(leaf.shape),
                          "transform": "pack_rows",
                          "source": f"{layer}.w",
                          "logical_k": logical_k[layer]})
        elif field == "w":
            descs.append({"name": f"{layer}.w", "kind": "weight",
                          "dtype": "f32", "shape": list(leaf.shape),
                          "transform": "none", "source": f"{layer}.w"})
        else:  # bn a / b
            descs.append({"name": f"{layer}.{field}", "kind": "weight",
                          "dtype": "f32", "shape": list(leaf.shape),
                          "transform": "none", "source": f"{layer}.{field}"})
    return descs


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def lower_model(cfg: model.ModelConfig, variant: str, batch: int,
                out_path: str) -> list:
    """Lower one (variant, batch) inference graph; returns input descs."""
    params = model.binarize_params(model.init_params(cfg, seed=0))
    if variant == "xnor":
        params = model.pack_params(cfg, params)
    x = jnp.zeros((batch, model.IMAGE_C, model.IMAGE_HW, model.IMAGE_HW),
                  jnp.float32)
    specs = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), (params, x))
    fn = model.make_inference_fn(cfg, variant)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return input_descriptors(cfg, params, x)


def lower_kernel(kernel: str, d: int, k: int, n: int, out_path: str) -> dict:
    """Lower one micro gemm executable (for PJRT-arm kernel benches)."""
    kw = (k + 31) // 32
    if kernel == "xnor":
        from .kernels.xnor_gemm import xnor_gemm
        fn = lambda wp, xp: xnor_gemm(wp, xp, k)  # noqa: E731
        specs = (jax.ShapeDtypeStruct((d, kw), jnp.uint32),
                 jax.ShapeDtypeStruct((kw, n), jnp.uint32))
        inputs = [{"dtype": "u32", "shape": [d, kw]},
                  {"dtype": "u32", "shape": [kw, n]}]
    elif kernel == "control":
        from .kernels.gemm import gemm_f32
        fn = gemm_f32
        specs = (jax.ShapeDtypeStruct((d, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
        inputs = [{"dtype": "f32", "shape": [d, k]},
                  {"dtype": "f32", "shape": [k, n]}]
    elif kernel == "optimized":
        fn = jnp.matmul
        specs = (jax.ShapeDtypeStruct((d, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
        inputs = [{"dtype": "f32", "shape": [d, k]},
                  {"dtype": "f32", "shape": [k, n]}]
    else:
        raise ValueError(kernel)
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(out_path, "w") as f:
        f.write(text)
    return {"kernel": kernel, "d": d, "k": k, "n": n, "inputs": inputs}


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def build(out_dir: str, quick: bool = False, log=print) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"format": 1, "models": [], "kernels": [],
                      "weights": {}, "datasets": {}}

    # 1. datasets ----------------------------------------------------------
    test_n = 256 if quick else TEST_N
    train_n = 256 if quick else TRAIN_N
    log(f"[aot] generating ShapeSet-10: test={test_n} train={train_n}")
    imgs_te, labels_te = dataset.make_split(test_n, seed=1000)
    dataset.save_bkd(os.path.join(out_dir, "dataset_test.bin"),
                     imgs_te, labels_te)
    imgs_tr, labels_tr = dataset.make_split(train_n, seed=2000)
    dataset.save_bkd(os.path.join(out_dir, "dataset_train.bin"),
                     imgs_tr, labels_tr)
    manifest["datasets"] = {
        "test": {"file": "dataset_test.bin", "count": test_n},
        "train": {"file": "dataset_train.bin", "count": train_n},
    }

    # 2. training (small model) -------------------------------------------
    steps = 20 if quick else TRAIN_STEPS
    cfg_small = model.ModelConfig(scale=SCALES["small"])
    log(f"[aot] training small BNN ({cfg_small.param_count():,} params, "
        f"{steps} steps)")
    t0 = time.time()
    lines = []
    tp, running, hist = train.train(
        cfg_small, steps=steps, batch=TRAIN_BATCH, lr=TRAIN_LR,
        train_n=train_n, seed=0, log_every=25,
        log=lambda s: (lines.append(s), log("  " + s)))
    params_small = model.fold_bn(tp, running)
    acc = train.eval_accuracy(cfg_small, params_small, imgs_te[:512],
                              labels_te[:512])
    log(f"[aot] trained in {time.time() - t0:.0f}s, test accuracy {acc:.3f}")
    with open(os.path.join(out_dir, "train_log.txt"), "w") as f:
        f.write("\n".join(lines) + f"\ntest_acc {acc:.4f}\n")
        f.write("".join(f"{i} {l:.5f} {a:.4f}\n" for i, l, a in hist))
    train.save_bkw(os.path.join(out_dir, "weights_small.bkw"),
                   cfg_small, params_small)

    cfg_full = model.ModelConfig(scale=SCALES["full"])
    params_full = model.binarize_params(model.init_params(cfg_full, seed=0))
    train.save_bkw(os.path.join(out_dir, "weights_full.bkw"),
                   cfg_full, params_full)
    manifest["weights"] = {
        "small": {"file": "weights_small.bkw", "scale": SCALES["small"],
                  "trained": True, "test_acc": acc},
        "full": {"file": "weights_full.bkw", "scale": SCALES["full"],
                 "trained": False},
    }

    # 3. whole-model HLOs ---------------------------------------------------
    scales = {"small": SCALES["small"]} if quick else SCALES
    for sname, scale in scales.items():
        cfg = model.ModelConfig(scale=scale)
        batches = (1,) if quick else BATCHES[sname]
        for variant in model.VARIANTS:
            for batch in batches:
                name = f"bnn_{sname}_{variant}_b{batch}"
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                t0 = time.time()
                descs = lower_model(cfg, variant, batch, path)
                log(f"[aot] lowered {name} "
                    f"({os.path.getsize(path) // 1024} KiB, "
                    f"{time.time() - t0:.1f}s)")
                manifest["models"].append({
                    "name": name, "file": f"{name}.hlo.txt",
                    "variant": variant, "scale": scale, "batch": batch,
                    "weights": sname,
                    "inputs": descs,
                    "output": {"dtype": "f32",
                               "shape": [batch, model.NUM_CLASSES]},
                })

    # 4. kernel micro HLOs --------------------------------------------------
    kshapes = KERNEL_SHAPES[:1] if quick else KERNEL_SHAPES
    for tag, d, k, n in kshapes:
        for kernel in ("xnor", "control", "optimized"):
            name = f"k_{kernel}_{tag}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            info = lower_kernel(kernel, d, k, n, path)
            info.update({"name": name, "file": f"{name}.hlo.txt",
                         "tag": tag, "logical_k": k})
            manifest["kernels"].append(info)
            log(f"[aot] lowered {name}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] wrote manifest with {len(manifest['models'])} models, "
        f"{len(manifest['kernels'])} kernels")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--quick", action="store_true",
                   help="tiny build for CI/tests")
    args = p.parse_args()
    build(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
