"""ShapeSet-10 — procedural CIFAR-10 stand-in (DESIGN.md §5).

The paper times BNN inference on the CIFAR-10 *test set*; inference speed
depends only on tensor shapes, which ShapeSet-10 matches exactly
(32x32x3 uint8, 10 classes, 50k train / 10k test).  Accuracy-parity
experiments (the paper's 89%-on-CIFAR-10 citation) run on this dataset
instead.

Classes (procedurally drawn, random color/position/size/noise):
  0 circle   1 square   2 triangle  3 cross      4 ring
  5 h-stripe 6 v-stripe 7 checker   8 dot-grid   9 diag-gradient

Binary export format "BKD1" (mirrored by rust/src/data/):
  magic  b"BKD1"
  u32le  count, height, width, channels
  count * { u8 label, h*w*c u8 pixels (HWC row-major) }
"""

from __future__ import annotations

import struct

import numpy as np

H = W = 32
C = 3
NUM_CLASSES = 10
CLASS_NAMES = [
    "circle", "square", "triangle", "cross", "ring",
    "h-stripe", "v-stripe", "checker", "dot-grid", "diag-gradient",
]

_YY, _XX = np.mgrid[0:H, 0:W].astype(np.float32)


def _draw(label: int, rng: np.random.Generator) -> np.ndarray:
    """Render one HxWx1 float mask in [0,1] for the given class."""
    cy = rng.uniform(10, 22)
    cx = rng.uniform(10, 22)
    r = rng.uniform(6, 12)
    yy, xx = _YY - cy, _XX - cx
    if label == 0:    # circle
        m = (yy * yy + xx * xx) <= r * r
    elif label == 1:  # square
        m = (np.abs(yy) <= r * 0.8) & (np.abs(xx) <= r * 0.8)
    elif label == 2:  # triangle (upward)
        m = (yy <= r * 0.7) & (yy >= -r * 0.7) & \
            (np.abs(xx) <= (yy + r * 0.7) * 0.6)
    elif label == 3:  # cross
        t = r * 0.3
        m = (np.abs(yy) <= t) | (np.abs(xx) <= t)
        m &= (np.abs(yy) <= r) & (np.abs(xx) <= r)
    elif label == 4:  # ring
        d2 = yy * yy + xx * xx
        m = (d2 <= r * r) & (d2 >= (r * 0.55) ** 2)
    elif label == 5:  # horizontal stripes
        p = rng.integers(3, 6)
        m = ((_YY.astype(np.int32) // p) % 2) == 0
    elif label == 6:  # vertical stripes
        p = rng.integers(3, 6)
        m = ((_XX.astype(np.int32) // p) % 2) == 0
    elif label == 7:  # checkerboard
        p = rng.integers(3, 6)
        m = (((_YY.astype(np.int32) // p) +
              (_XX.astype(np.int32) // p)) % 2) == 0
    elif label == 8:  # dot grid
        p = rng.integers(5, 8)
        m = ((_YY.astype(np.int32) % p) < 2) & ((_XX.astype(np.int32) % p) < 2)
    elif label == 9:  # diagonal gradient (no mask; handled below)
        g = (_YY + _XX) / (H + W - 2)
        if rng.random() < 0.5:
            g = 1.0 - g
        return g
    else:
        raise ValueError(label)
    return m.astype(np.float32)


def make_image(label: int, rng: np.random.Generator) -> np.ndarray:
    """One HxWxC uint8 image for `label`."""
    fg = rng.uniform(0.55, 1.0, size=3)
    bg = rng.uniform(0.0, 0.45, size=3)
    if rng.random() < 0.3:  # sometimes dark-on-light
        fg, bg = bg, fg
    mask = _draw(label, rng)[:, :, None]
    img = mask * fg[None, None, :] + (1.0 - mask) * bg[None, None, :]
    img = img + rng.normal(0.0, 0.06, size=img.shape)
    return (np.clip(img, 0.0, 1.0) * 255.0).astype(np.uint8)


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate n images/labels with a balanced class distribution."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([make_image(int(l), rng) for l in labels])
    return imgs, labels.astype(np.uint8)


def normalize(imgs: np.ndarray) -> np.ndarray:
    """uint8 HWC batch -> float32 NCHW in [-1, 1] (the model's input)."""
    x = imgs.astype(np.float32) / 127.5 - 1.0
    return np.ascontiguousarray(x.transpose(0, 3, 1, 2))


def save_bkd(path: str, imgs: np.ndarray, labels: np.ndarray) -> None:
    """Write the BKD1 binary format consumed by rust/src/data/."""
    n, h, w, c = imgs.shape
    assert labels.shape == (n,)
    with open(path, "wb") as f:
        f.write(b"BKD1")
        f.write(struct.pack("<IIII", n, h, w, c))
        for i in range(n):
            f.write(struct.pack("<B", int(labels[i])))
            f.write(imgs[i].tobytes())


def load_bkd(path: str) -> tuple[np.ndarray, np.ndarray]:
    """Read a BKD1 file back (used by tests for round-trip checks)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"BKD1", magic
        n, h, w, c = struct.unpack("<IIII", f.read(16))
        imgs = np.empty((n, h, w, c), np.uint8)
        labels = np.empty((n,), np.uint8)
        for i in range(n):
            labels[i] = struct.unpack("<B", f.read(1))[0]
            imgs[i] = np.frombuffer(f.read(h * w * c),
                                    np.uint8).reshape(h, w, c)
    return imgs, labels
