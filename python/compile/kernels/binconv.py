"""Fused binarized convolution — the paper's Figure-3 forward graph.

    input (f32, NCHW)
      -> im2col                       (lax.conv_general_dilated_patches)
      -> encode cols (pack_cols)      (Pallas, Sec. 3.1)
      -> xnor-bitcount gemm           (Pallas, Sec. 3.2)
      -> col2im (reshape/transpose)
    weights arrive ALREADY packed [D, Kw] — the paper packs them offline
    ('it manually skips the im2col operation', Sec. 3.1).

Also provides the two comparison graphs used by the Table-2 arms:
  * conv2d_control  — Figure-2 graph with the naive Pallas f32 gemm
  * conv2d_optimized — lax.conv (XLA's vendor-optimized path)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .gemm import gemm_f32
from .pack import pack_cols
from .ref import sign
from .xnor_gemm import xnor_gemm


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           pad: int = 0) -> jax.Array:
    """im2col via XLA's patch extractor: [B,C,H,W] -> [C*kh*kw, B*OH*OW].

    `conv_general_dilated_patches` returns patches with the feature axis
    ordered (c, i, j), matching ref.im2col_ref and the rust engine.
    """
    b = x.shape[0]
    patches = lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [B, C*kh*kw, OH, OW]
    k = patches.shape[1]
    oh, ow = patches.shape[2], patches.shape[3]
    # [B, K, OH, OW] -> [K, B*OH*OW] with column order (b, oh, ow)
    return patches.transpose(1, 0, 2, 3).reshape(k, b * oh * ow)


def _out_hw(h: int, w: int, kh: int, kw: int, stride: int,
            pad: int) -> tuple[int, int]:
    return ((h + 2 * pad - kh) // stride + 1,
            (w + 2 * pad - kw) // stride + 1)


def binconv2d(x: jax.Array, wp: jax.Array, shape: tuple[int, int, int, int],
              stride: int = 1, pad: int = 0) -> jax.Array:
    """Binarized conv with pre-packed weights (Figure 3).

    x:  [B, C, H, W] float activations (binarized *inside*: the column
        matrix is sign-encoded by pack_cols, so zero spatial padding maps
        to +1 exactly like ref.binconv2d_ref).
    wp: [D, ceil(C*kh*kw/32)] packed uint32 weights (pack_rows of the
        sign-binarized [D, C*kh*kw] weight matrix).
    shape: the logical (D, C, kh, kw) of the unpacked weight.
    Returns [B, D, OH, OW] float32 (exact integers).
    """
    d, c, kh, kw = shape
    b, cx, h, w = x.shape
    assert cx == c, (x.shape, shape)
    k = c * kh * kw
    oh, ow = _out_hw(h, w, kh, kw, stride, pad)

    cols = im2col(x, kh, kw, stride, pad)           # [K, B*OH*OW] f32
    xp = pack_cols(cols)                            # [Kw, B*OH*OW] u32
    out = xnor_gemm(wp, xp, k)                      # [D, B*OH*OW] i32
    out = out.astype(jnp.float32)
    return out.reshape(d, b, oh, ow).transpose(1, 0, 2, 3)


def conv2d_control(x: jax.Array, w: jax.Array, stride: int = 1,
                   pad: int = 0, *, weights_pm1: bool = False) -> jax.Array:
    """Control-group conv (Figure 2): im2col + naive Pallas f32 gemm.

    Weights and the column matrix are sign-binarized (same network as the
    xnor arm) but computed in float-32 with Gemm-Accumulation — the
    paper's 'simulation' of a BNN.  `weights_pm1=True` asserts the caller
    already passes {-1,+1} weights and skips the in-graph sign() — a §Perf
    L2 optimization (the exported BKW1 weights are pre-binarized, so the
    lowered inference graphs avoid D*K selects per layer).
    """
    b, c, h, wd = x.shape
    d, _, kh, kw = w.shape
    oh, ow = _out_hw(h, wd, kh, kw, stride, pad)
    cols = sign(im2col(x, kh, kw, stride, pad))     # [K, B*OH*OW]
    wmat = w.reshape(d, c * kh * kw)                # [D, K]
    if not weights_pm1:
        wmat = sign(wmat)
    out = gemm_f32(wmat, cols)                      # [D, B*OH*OW]
    return out.reshape(d, b, oh, ow).transpose(1, 0, 2, 3)


def conv2d_optimized(x: jax.Array, w: jax.Array, stride: int = 1,
                     pad: int = 0, *, weights_pm1: bool = False) -> jax.Array:
    """Optimized-baseline conv: sign-binarized operands, XLA's lax.conv.

    Stands in for cuDNN/MKL-backed PyTorch (Table 2 row 1).  The zero
    spatial padding is applied in the *sign domain* (pad the binarized
    column matrix with sign(0)=+1) to stay numerically identical to the
    other two arms: we pre-binarize x, pad with +1 explicitly, then run
    the vendor conv with no implicit padding.
    """
    xb = sign(x)
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, 0), (pad, pad), (pad, pad)),
                     constant_values=1.0)
    return lax.conv_general_dilated(
        xb, w if weights_pm1 else sign(w), window_strides=(stride, stride),
        padding=[(0, 0), (0, 0)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
