"""Pure-jnp correctness oracles for the BitKernel L1 kernels.

Everything here is deliberately simple, un-tiled jnp so it can serve as the
ground truth the Pallas kernels (pack.py / xnor_gemm.py / gemm.py /
binconv.py) are tested against.  The chain of trust is:

    float matmul on {-1,+1} values            (mathematical ground truth)
      == xnor_gemm_packed_ref (this file)     (packed-domain oracle)
      == pallas xnor_gemm                     (the kernel under test)

Bit-packing convention (must match rust/src/bitops/):
  * sign(x) = +1 if x >= 0 else -1
  * encoding: bit 1 <=> value +1, bit 0 <=> value -1
  * little-endian bit order: bit i of word w encodes logical index w*32+i
  * the reduction axis K is padded up to a multiple of 32 with encoding 0
    (value -1) on BOTH operands; each padded position contributes
    xnor = 1 -> +1 to the popcount sum, so the packed gemm subtracts n_pad.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

WORD = 32  # bits per packed word (uint32)


# ---------------------------------------------------------------------------
# sign / binarize
# ---------------------------------------------------------------------------

def sign(x: jax.Array) -> jax.Array:
    """Deterministic binarization: sign(x) in {-1.0, +1.0}, sign(0) = +1.

    This is the paper's 'Deterministic Binarization' (Sec. 4.2); mapping 0
    to +1 keeps the value domain bijective with the bit encoding below.
    """
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def encode_bits(x: jax.Array) -> jax.Array:
    """Value domain -> encoding domain: {-1,+1} (or any float) -> {0,1} u32."""
    return (x >= 0).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# bit packing
# ---------------------------------------------------------------------------

def padded_k(k: int) -> int:
    """K rounded up to a multiple of the word size."""
    return (k + WORD - 1) // WORD * WORD


def pack_rows_ref(w: jax.Array) -> jax.Array:
    """Pack a float [D, K] matrix row-wise into uint32 [D, ceil(K/32)].

    The paper packs the weight matrix 'in the direction of rows'
    (Sec. 3.1): consecutive elements of a row share a word.  Padding
    positions (K..Kpad) get encoding 0 (value -1).
    """
    d, k = w.shape
    kp = padded_k(k)
    bits = encode_bits(w)
    if kp != k:
        bits = jnp.pad(bits, ((0, 0), (0, kp - k)))
    bits = bits.reshape(d, kp // WORD, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def pack_cols_ref(x: jax.Array) -> jax.Array:
    """Pack a float [K, N] matrix column-wise into uint32 [ceil(K/32), N].

    The im2col'd input is packed 'in the direction of columns' (Sec. 3.1):
    consecutive elements of a column share a word.
    """
    k, n = x.shape
    kp = padded_k(k)
    bits = encode_bits(x)
    if kp != k:
        bits = jnp.pad(bits, ((0, kp - k), (0, 0)))
    bits = bits.reshape(kp // WORD, WORD, n)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, :, None], axis=1, dtype=jnp.uint32)


def unpack_rows_ref(wp: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_rows_ref back to the value domain {-1,+1} f32."""
    d, kw = wp.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (wp[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    vals = bits.reshape(d, kw * WORD)[:, :k].astype(jnp.float32)
    return vals * 2.0 - 1.0


def unpack_cols_ref(xp: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_cols_ref back to the value domain {-1,+1} f32."""
    kw, n = xp.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (xp[:, None, :] >> shifts[None, :, None]) & jnp.uint32(1)
    vals = bits.reshape(kw * WORD, n)[:k, :].astype(jnp.float32)
    return vals * 2.0 - 1.0


# ---------------------------------------------------------------------------
# gemm oracles
# ---------------------------------------------------------------------------

def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain float matmul — ground truth for both kernels."""
    return jnp.matmul(a, b)


def xnor_gemm_packed_ref(wp: jax.Array, xp: jax.Array, k: int) -> jax.Array:
    """Packed-domain oracle for the paper's Sec. 3.2 formula.

    a[i,j] = sum_w ( 2 * popcount(~(wp[i,w] ^ xp[w,j])) - 32 ) - n_pad

    with n_pad = Kpad - k correcting for the zero-encoded padding on both
    operands (each padded bit xnors to 1 and would otherwise contribute +1).
    Returns int32 [D, N]; exact (no float rounding).
    """
    kw = wp.shape[1]
    assert xp.shape[0] == kw, (wp.shape, xp.shape)
    n_pad = kw * WORD - k
    xnor = jnp.bitwise_not(wp[:, :, None] ^ xp[None, :, :])  # [D, Kw, N]
    pc = lax.population_count(xnor).astype(jnp.int32)
    return jnp.sum(2 * pc - WORD, axis=1) - jnp.int32(n_pad)


def xnor_gemm_value_ref(w: jax.Array, x: jax.Array) -> jax.Array:
    """Value-domain reference: binarize then float-matmul. [D,K] x [K,N]."""
    return jnp.matmul(sign(w), sign(x))


# ---------------------------------------------------------------------------
# im2col / conv oracles (Figure 1 / Figure 2 / Figure 3 of the paper)
# ---------------------------------------------------------------------------

def im2col_ref(x: jax.Array, kh: int, kw: int, stride: int = 1,
               pad: int = 0) -> jax.Array:
    """im2col for NCHW input [B, C, H, W] -> [C*kh*kw, B*OH*OW].

    Patch-row layout ordered (c, i, j) to match
    lax.conv_general_dilated_patches and the rust implementation; the
    column index is ordered (b, oh, ow).
    """
    b, c, h, w = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for ci in range(c):
        for i in range(kh):
            for j in range(kw):
                patch = x[:, ci, i:i + (oh - 1) * stride + 1:stride,
                          j:j + (ow - 1) * stride + 1:stride]
                cols.append(patch.reshape(b * oh * ow))
    return jnp.stack(cols, axis=0)  # [C*kh*kw, B*OH*OW]


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               pad: int = 0) -> jax.Array:
    """Direct convolution oracle via lax.conv. x:[B,C,H,W], w:[D,C,kh,kw]."""
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_im2col_ref(x: jax.Array, w: jax.Array, stride: int = 1,
                      pad: int = 0) -> jax.Array:
    """Figure-2 forward graph: im2col -> gemm -> col2im(reshape)."""
    b, c, h, wd = x.shape
    d, _, kh, kw = w.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    cols = im2col_ref(x, kh, kw, stride, pad)          # [K, B*OH*OW]
    wmat = w.reshape(d, c * kh * kw)                   # [D, K]
    out = gemm_ref(wmat, cols)                         # [D, B*OH*OW]
    return out.reshape(d, b, oh, ow).transpose(1, 0, 2, 3)


def binconv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
                  pad: int = 0) -> jax.Array:
    """Figure-3 forward graph oracle, value domain.

    Binarized convolution: im2col, then sign() both the column matrix and
    the weight matrix, then float gemm.  NOTE on zero padding: spatial
    padding inserts 0s which sign() maps to +1 — this is deliberate and
    both the oracle and the packed kernels binarize the *padded* column
    matrix, so they agree bit-for-bit.
    """
    b, c, h, wd = x.shape
    d, _, kh, kw = w.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    cols = sign(im2col_ref(x, kh, kw, stride, pad))
    wmat = sign(w.reshape(d, c * kh * kw))
    out = gemm_ref(wmat, cols)
    return out.reshape(d, b, oh, ow).transpose(1, 0, 2, 3)
