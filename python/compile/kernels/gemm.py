"""Pallas control-group gemm — the paper's Sec. 4.3 baseline.

The paper's control group is the SAME im2col forward graph with a plain
float-32 Gemm-Accumulation and *no vendor library* (no cuDNN/MKL).  To
keep that property here, the tile product is computed as an explicit
broadcast-multiply-reduce (one MAC per logical element) rather than
`jnp.dot`, so XLA cannot substitute its optimized dot emitter for the
inner product — this is the float kernel the xnor kernel is measured
against, with identical tiling/grid structure so the only difference is
the arithmetic (32 f32 MACs vs 1 xnor + 1 popcount per 32 elements).

interpret=True: see DESIGN.md §3.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_D = 128
_BLOCK_N = 128
_BLOCK_K = 256  # logical (unpacked) reduction elements per step


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step of the naive float gemm."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                                    # [bd, bk] f32
    b = b_ref[...]                                    # [bk, bn] f32
    # Naive MAC loop, vectorized but not dot-fused: mirrors the control
    # group's un-optimized Gemm-Accumulation.
    o_ref[...] += jnp.sum(a[:, :, None] * b[None, :, :], axis=1)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_d", "block_n", "block_k"))
def gemm_f32(a: jax.Array, b: jax.Array, *, block_d: int = _BLOCK_D,
             block_n: int = _BLOCK_N, block_k: int = _BLOCK_K) -> jax.Array:
    """Control-group float gemm: f32 [D, K] x f32 [K, N] -> f32 [D, N]."""
    d, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)

    bd = min(block_d, max(d, 1))
    bn = min(block_n, max(n, 1))
    bk = min(block_k, max(k, 1))
    dp, np_, kp = _ceil_to(d, bd), _ceil_to(n, bn), _ceil_to(k, bk)

    if (dp, kp) != (d, k):
        a = jnp.pad(a, ((0, dp - d), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(dp // bd, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, np_), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:d, :n]
