"""BitKernel L1 kernels: Pallas xnor-bitcount compute + pure-jnp oracles."""

from . import binconv, gemm, pack, ref, xnor_gemm  # noqa: F401

__all__ = ["binconv", "gemm", "pack", "ref", "xnor_gemm"]
