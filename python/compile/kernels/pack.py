"""Pallas encode kernels: float tensors -> 1-bit packed uint32 matrices.

This is the paper's Sec. 3.1 'Encoding' step, rethought for TPU:

  * the paper encodes with a CUDA thread per output word; here a Pallas
    grid program owns a (rows x words) VMEM tile and produces all its
    words with vectorized shift-accumulate on the VPU,
  * bit i of word w encodes logical reduction index w*32 + i (little
    endian), encoding 1 <=> value +1 — identical to ref.py and to
    rust/src/bitops/.

Both kernels run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import WORD, padded_k

# Default tile sizes.  A pack tile touches bd*WORD*bw f32 in + bd*bw u32
# out; with bd=256, bw=8 that is 256*256*4 B = 256 KiB in / 8 KiB out —
# comfortably inside a 16 MiB VMEM budget together with double buffering.
_BLOCK_ROWS = 256
_BLOCK_WORDS = 8


def _pack_rows_kernel(x_ref, o_ref):
    """One grid step packs a [bd, bw*WORD] f32 tile -> [bd, bw] u32 tile."""
    x = x_ref[...]                                   # [bd, bw*WORD] f32
    bd, kb = x.shape
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(bd, kb // WORD, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(bits << shifts[None, None, :], axis=-1,
                         dtype=jnp.uint32)


def _pack_cols_kernel(x_ref, o_ref):
    """One grid step packs a [bw*WORD, bn] f32 tile -> [bw, bn] u32 tile."""
    x = x_ref[...]                                   # [bw*WORD, bn] f32
    kb, bn = x.shape
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(kb // WORD, WORD, bn)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(bits << shifts[None, :, None], axis=1,
                         dtype=jnp.uint32)


def _pad_to(x: jax.Array, axis: int, size: int, value: float) -> jax.Array:
    cur = x.shape[axis]
    if cur == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - cur)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words"))
def pack_rows(w: jax.Array, *, block_rows: int = _BLOCK_ROWS,
              block_words: int = _BLOCK_WORDS) -> jax.Array:
    """Pack float [D, K] row-wise into uint32 [D, ceil(K/32)] via Pallas.

    K is padded to a multiple of 32 with value -1 (encoding 0); D and the
    word count are padded to the tile grid and cropped back afterwards.
    """
    d, k = w.shape
    kw = padded_k(k) // WORD
    bd = min(block_rows, max(d, 1))
    bw = min(block_words, max(kw, 1))
    dp = -(-d // bd) * bd
    kwp = -(-kw // bw) * bw
    # Pad: rows with anything (cropped), K with -1 so padding encodes 0.
    wp = _pad_to(_pad_to(w, 1, kwp * WORD, -1.0), 0, dp, -1.0)
    out = pl.pallas_call(
        _pack_rows_kernel,
        grid=(dp // bd, kwp // bw),
        in_specs=[pl.BlockSpec((bd, bw * WORD), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bd, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, kwp), jnp.uint32),
        interpret=True,
    )(wp)
    return out[:d, :kw]


@functools.partial(jax.jit, static_argnames=("block_words", "block_cols"))
def pack_cols(x: jax.Array, *, block_words: int = _BLOCK_WORDS,
              block_cols: int = _BLOCK_ROWS) -> jax.Array:
    """Pack float [K, N] column-wise into uint32 [ceil(K/32), N] via Pallas."""
    k, n = x.shape
    kw = padded_k(k) // WORD
    bw = min(block_words, max(kw, 1))
    bn = min(block_cols, max(n, 1))
    kwp = -(-kw // bw) * bw
    np_ = -(-n // bn) * bn
    xp = _pad_to(_pad_to(x, 0, kwp * WORD, -1.0), 1, np_, -1.0)
    out = pl.pallas_call(
        _pack_cols_kernel,
        grid=(kwp // bw, np_ // bn),
        in_specs=[pl.BlockSpec((bw * WORD, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bw, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kwp, np_), jnp.uint32),
        interpret=True,
    )(xp)
    return out[:kw, :n]
