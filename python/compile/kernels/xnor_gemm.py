"""Pallas xnor-bitcount gemm — the paper's core kernel (Sec. 3.2).

Computes, for packed uint32 operands wp [D, Kw] and xp [Kw, N],

    a[i,j] = sum_w ( 2 * popcount(~(wp[i,w] ^ xp[w,j])) - 32 ) - n_pad

which equals the float matmul of the underlying {-1,+1} matrices exactly
(integer arithmetic, no rounding).

TPU adaptation of the paper's CUDA kernel (DESIGN.md §3):
  * the CUDA block/thread decomposition becomes a Pallas grid over
    (D-tiles, N-tiles, K-tiles); `BlockSpec` index maps express the
    HBM->VMEM schedule the paper expressed with threadblocks,
  * `__popc()` becomes `lax.population_count`, an elementwise VPU op,
  * the K reduction is the innermost grid dimension, accumulating into the
    output tile kept resident in VMEM (revisited, not re-fetched),
  * packing gives a 32x denser reduction: a [bd, bk] uint32 tile carries
    bd*bk*32 logical elements.

VMEM budget per grid step (defaults bd=bn=128, bk=8):
    wp tile 128*8*4 B = 4 KiB, xp tile 8*128*4 B = 4 KiB,
    xnor intermediate 128*8*128*4 B = 512 KiB, acc 128*128*4 B = 64 KiB
  ~ 0.6 MiB total, far under 16 MiB — headroom for double buffering.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import WORD

_BLOCK_D = 128
_BLOCK_N = 128
_BLOCK_K = 8  # packed words per reduction step = 256 logical elements


def _xnor_gemm_kernel(wp_ref, xp_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += xnor-popcount(w[i,k], x[k,j])."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wp = wp_ref[...]                                  # [bd, bk] u32
    xp = xp_ref[...]                                  # [bk, bn] u32
    xnor = jnp.bitwise_not(wp[:, :, None] ^ xp[None, :, :])  # [bd, bk, bn]
    pc = lax.population_count(xnor).astype(jnp.int32)
    # sum_w (2*pc - 32)  ==  2 * sum_w pc - 32*bk   (hoist the affine part)
    acc = 2 * jnp.sum(pc, axis=1) - jnp.int32(WORD * wp.shape[1])
    o_ref[...] += acc


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit,
                   static_argnames=("k", "block_d", "block_n", "block_k"))
def xnor_gemm(wp: jax.Array, xp: jax.Array, k: int, *,
              block_d: int = _BLOCK_D, block_n: int = _BLOCK_N,
              block_k: int = _BLOCK_K) -> jax.Array:
    """Packed xnor gemm: uint32 [D, Kw] x uint32 [Kw, N] -> int32 [D, N].

    `k` is the LOGICAL reduction length (before padding to a multiple of
    32); the result subtracts the n_pad = Kw*32 - k correction for the
    zero-encoded padding present on both operands.

    Zero-padding of the D/N/Kw tile grid is folded into the same
    correction: a padded K word is 0 on both operands, xnors to ~0
    (popcount 32) and contributes 2*32 - 32 = +32 = +1 per bit, exactly
    like the 32-alignment padding bits — all covered by n_pad below.
    """
    d, kw = wp.shape
    kw2, n = xp.shape
    assert kw == kw2, (wp.shape, xp.shape)
    assert k <= kw * WORD, (k, kw)

    bd = min(block_d, max(d, 1))
    bn = min(block_n, max(n, 1))
    bk = min(block_k, max(kw, 1))
    dp, np_, kwp = _ceil_to(d, bd), _ceil_to(n, bn), _ceil_to(kw, bk)

    if (dp, kwp) != (d, kw):
        wp = jnp.pad(wp, ((0, dp - d), (0, kwp - kw)))
    if (kwp, np_) != (kw, n):
        xp = jnp.pad(xp, ((0, kwp - kw), (0, np_ - n)))

    out = pl.pallas_call(
        _xnor_gemm_kernel,
        grid=(dp // bd, np_ // bn, kwp // bk),
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, np_), jnp.int32),
        interpret=True,
    )(wp, xp)

    # Correction: every bit position beyond the logical k (both the
    # 32-alignment padding inside the last real word range and the whole
    # zero words added for grid alignment) is 0 on both operands, xnors to
    # 1, and contributed +1 to the accumulated sum.
    n_pad = kwp * WORD - k
    out = out - jnp.int32(n_pad)
    return out[:d, :n]
