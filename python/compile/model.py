"""L2: the Binarized Neural Network (Courbariaux et al. 2016) in JAX.

This is the network of the paper's Sec. 4.2, width-scalable:

    (2x 128C3) - MP2 - (2x 256C3) - MP2 - (2x 512C3) - MP2
    - 1024FC - 1024FC - 10FC          (BatchNorm after every layer)

All conv layers beyond the first, and all FC layers, carry {-1,+1}
weights and consume {-1,+1} activations.  The first conv keeps the float
input image (binarizing raw pixels destroys the signal; Courbariaux et
al. treat the first layer in fixed point) — it is computed identically in
every Table-2 arm, so the arms differ ONLY in the binarized-layer kernel:

    variant "xnor"      — Pallas encode + xnor-bitcount  (Figure 3)
    variant "control"   — Pallas naive f32 gemm          (Figure 2, Sec 4.3)
    variant "optimized" — lax.conv / jnp.dot             ("PyTorch" row)

Inference-time BatchNorm is folded to a per-channel affine (a, b); Htanh
is omitted at inference because sign(htanh(x)) == sign(x) and every
binarized layer re-binarizes its input internally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import binconv
from .kernels.gemm import gemm_f32
from .kernels.pack import pack_cols, pack_rows
from .kernels.ref import sign
from .kernels.xnor_gemm import xnor_gemm

VARIANTS = ("xnor", "control", "optimized")
NUM_CLASSES = 10
IMAGE_HW = 32
IMAGE_C = 3


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    cin: int
    cout: int
    ksize: int = 3
    stride: int = 1
    pad: int = 1
    pool: bool = False       # 2x2 max-pool after the conv
    binarized: bool = True   # False only for conv1 (float input)

    @property
    def k(self) -> int:
        """Logical gemm reduction length K = C * kh * kw."""
        return self.cin * self.ksize * self.ksize


@dataclasses.dataclass(frozen=True)
class FcSpec:
    name: str
    din: int
    dout: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Width-scaled BNN; scale=1.0 is the paper's full network."""
    scale: float = 1.0

    def _c(self, w: int) -> int:
        return max(8, int(round(w * self.scale)))

    @property
    def widths(self) -> List[int]:
        return [self._c(128), self._c(128), self._c(256), self._c(256),
                self._c(512), self._c(512)]

    @property
    def fc_widths(self) -> List[int]:
        return [self._c(1024), self._c(1024), NUM_CLASSES]

    @property
    def conv_specs(self) -> List[ConvSpec]:
        w = self.widths
        chans = [IMAGE_C] + w
        return [ConvSpec(
            name=f"conv{i + 1}", cin=chans[i], cout=chans[i + 1],
            pool=(i % 2 == 1),           # pool after conv2, conv4, conv6
            binarized=(i != 0),
        ) for i in range(6)]

    @property
    def fc_specs(self) -> List[FcSpec]:
        hw = IMAGE_HW // 8               # three 2x2 pools: 32 -> 4
        dins = [self.widths[-1] * hw * hw] + self.fc_widths[:-1]
        return [FcSpec(f"fc{i + 1}", dins[i], self.fc_widths[i])
                for i in range(3)]

    def param_count(self) -> int:
        n = sum(s.cout * s.k for s in self.conv_specs)
        n += sum(s.din * s.dout for s in self.fc_specs)
        n += 2 * (sum(s.cout for s in self.conv_specs)
                  + sum(s.dout for s in self.fc_specs))
        return n


# ---------------------------------------------------------------------------
# parameter initialization / transforms
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Random latent floats + identity BN — the untrained starting point."""
    rng = np.random.default_rng(seed)
    params: Dict[str, Any] = {}
    for s in cfg.conv_specs:
        params[s.name] = {"w": jnp.asarray(
            rng.normal(0, 1.0 / np.sqrt(s.k),
                       size=(s.cout, s.cin, s.ksize, s.ksize))
            .astype(np.float32))}
        params[f"bn_{s.name}"] = {"a": jnp.ones((s.cout,), jnp.float32),
                                  "b": jnp.zeros((s.cout,), jnp.float32)}
    for s in cfg.fc_specs:
        params[s.name] = {"w": jnp.asarray(
            rng.normal(0, 1.0 / np.sqrt(s.din), size=(s.dout, s.din))
            .astype(np.float32))}
        params[f"bn_{s.name}"] = {"a": jnp.ones((s.dout,), jnp.float32),
                                  "b": jnp.zeros((s.dout,), jnp.float32)}
    return params


def binarize_params(params: Dict[str, Any]) -> Dict[str, Any]:
    """Latent floats -> exported {-1,+1} weights (BN affine untouched)."""
    return {k: ({"w": sign(v["w"])} if "w" in v else dict(v))
            for k, v in params.items()}


def _per_channel_mean_abs(w: jax.Array) -> jax.Array:
    """E|w| per output channel (axis 0) — XNOR-Net's optimal scale."""
    return jnp.abs(w.reshape(w.shape[0], -1)).mean(axis=1)


def alpha_params(cfg: ModelConfig, params: Dict[str, Any]) -> Dict[str, Any]:
    """Latent floats -> the xnor_alpha scheme's export pytree.

    Every binarized layer carries sign(w) plus a per-output-channel
    scale alpha = E|w| (Rastegari et al. 2016: the L2-optimal scalar
    for approximating w by alpha * sign(w)).  Non-binarized layers
    (conv1) keep plain sign(w), matching binarize_params.
    """
    alpha_layers = ({s.name for s in cfg.conv_specs if s.binarized}
                    | {s.name for s in cfg.fc_specs})
    out: Dict[str, Any] = {}
    for k, v in params.items():
        if "w" not in v:
            out[k] = dict(v)
        elif k in alpha_layers:
            out[k] = {"w": sign(v["w"]),
                      "alpha": _per_channel_mean_abs(v["w"])}
        else:
            out[k] = {"w": sign(v["w"])}
    return out


def ternarize_params(params: Dict[str, Any],
                     delta_scale: float = 0.7) -> Dict[str, Any]:
    """Latent floats -> {-1, 0, +1} ternary weights (TWN thresholding).

    Per output channel, weights inside (-delta, +delta) with
    delta = delta_scale * E|w| become exact 0.0; the rest keep their
    sign — Li & Liu 2016's threshold heuristic.  BN affines untouched.
    """
    out: Dict[str, Any] = {}
    for k, v in params.items():
        if "w" not in v:
            out[k] = dict(v)
            continue
        w = v["w"]
        delta = delta_scale * _per_channel_mean_abs(w)
        d = delta.reshape((-1,) + (1,) * (w.ndim - 1))
        out[k] = {"w": jnp.where(
            w > d, 1.0, jnp.where(w < -d, -1.0, 0.0)
        ).astype(jnp.float32)}
    return out


def pack_params(cfg: ModelConfig, params: Dict[str, Any]) -> Dict[str, Any]:
    """Float params -> the xnor variant's packed-weight pytree.

    conv1 stays float (its input is not binarized); every other conv and
    all FC weights become uint32 [D, ceil(K/32)] via pack_rows of the
    sign-binarized [D, K] weight matrix — the paper's offline weight
    encoding (Sec. 3.1).
    """
    out: Dict[str, Any] = {}
    for s in cfg.conv_specs:
        w = params[s.name]["w"]
        if s.binarized:
            out[s.name] = {"wp": pack_rows(sign(w.reshape(s.cout, s.k)))}
        else:
            out[s.name] = {"w": sign(w)}
        out[f"bn_{s.name}"] = dict(params[f"bn_{s.name}"])
    for s in cfg.fc_specs:
        out[s.name] = {"wp": pack_rows(sign(params[s.name]["w"]))}
        out[f"bn_{s.name}"] = dict(params[f"bn_{s.name}"])
    return out


# ---------------------------------------------------------------------------
# inference forward (the AOT-lowered graphs)
# ---------------------------------------------------------------------------

def _bn_nchw(h: jax.Array, bn: Dict[str, jax.Array]) -> jax.Array:
    return h * bn["a"][None, :, None, None] + bn["b"][None, :, None, None]


def _bn_nf(h: jax.Array, bn: Dict[str, jax.Array]) -> jax.Array:
    return h * bn["a"][None, :] + bn["b"][None, :]


def maxpool2(h: jax.Array) -> jax.Array:
    """2x2 max pool, stride 2, NCHW."""
    b, c, hh, ww = h.shape
    h = h.reshape(b, c, hh // 2, 2, ww // 2, 2)
    return h.max(axis=(3, 5))


def _conv_first(x: jax.Array, w: jax.Array) -> jax.Array:
    """conv1: float input, {-1,+1} weights — identical in every arm.

    Weights arrive pre-binarized from the BKW1 export (binarize_params /
    fold_bn), so no in-graph sign() — §Perf L2: the lowered graphs carry
    no redundant weight binarization.
    """
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def apply_inference(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array,
                    variant: str) -> jax.Array:
    """Full inference forward -> logits [B, 10].

    `params` is the float pytree for variants control/optimized and the
    packed pytree (pack_params) for variant xnor.  Weight tensors MUST be
    pre-binarized {-1,+1} (binarize_params / fold_bn guarantee this; the
    graphs skip the redundant in-graph sign() — §Perf L2).  All three
    variants produce IDENTICAL logits — the network is the same; only the
    conv/FC kernel differs (the paper's premise, our core invariant).
    """
    assert variant in VARIANTS, variant
    h = x
    for s in cfg.conv_specs:
        if not s.binarized:
            h = _conv_first(h, params[s.name]["w"])
        elif variant == "xnor":
            h = binconv.binconv2d(h, params[s.name]["wp"],
                                  (s.cout, s.cin, s.ksize, s.ksize),
                                  s.stride, s.pad)
        elif variant == "control":
            h = binconv.conv2d_control(h, params[s.name]["w"],
                                       s.stride, s.pad, weights_pm1=True)
        else:
            h = binconv.conv2d_optimized(h, params[s.name]["w"],
                                         s.stride, s.pad, weights_pm1=True)
        if s.pool:
            h = maxpool2(h)
        h = _bn_nchw(h, params[f"bn_{s.name}"])

    b = h.shape[0]
    h = h.reshape(b, -1)                       # flatten in (c, h, w) order
    for s in cfg.fc_specs:
        if variant == "xnor":
            xp = pack_cols(h.T)                # encode cols of [K, B]
            h = xnor_gemm(params[s.name]["wp"], xp,
                          s.din).T.astype(jnp.float32)
        elif variant == "control":
            h = gemm_f32(params[s.name]["w"], sign(h.T)).T
        else:
            h = jnp.dot(sign(h), params[s.name]["w"].T)
        h = _bn_nf(h, params[f"bn_{s.name}"])
    return h


def make_inference_fn(cfg: ModelConfig, variant: str):
    """(params, x) -> logits closure suitable for jax.jit / AOT lowering."""
    def fn(params, x):
        return apply_inference(cfg, params, x, variant)
    return fn


# ---------------------------------------------------------------------------
# training forward (STE; build-time only, never lowered to rust)
# ---------------------------------------------------------------------------

def binact(x: jax.Array) -> jax.Array:
    """Binarize activation with the Htanh straight-through estimator.

    Forward: sign(x).  Backward: 1_{|x| <= 1} (the derivative of Htanh),
    the paper's Sec. 4.2 answer to the gradient-mismatch problem.
    """
    clipped = jnp.clip(x, -1.0, 1.0)
    return clipped + lax.stop_gradient(sign(x) - clipped)


def binweight(w: jax.Array) -> jax.Array:
    """Binarize weight with identity STE (gradients reach the latent w)."""
    return w + lax.stop_gradient(sign(w) - w)


def batchnorm_train(h: jax.Array, gamma: jax.Array, beta: jax.Array,
                    axes: tuple, eps: float = 1e-4):
    """BatchNorm over `axes` with batch statistics; returns (out, mu, var).

    The channel axis is axis 1 for NCHW and axis 1 for [B, F] — both
    reshape the per-channel stats to broadcast over the rest.
    """
    mu = h.mean(axis=axes)
    var = h.var(axis=axes)
    shape = [1] * h.ndim
    shape[1] = -1
    mu_b, var_b = mu.reshape(shape), var.reshape(shape)
    out = (h - mu_b) / jnp.sqrt(var_b + eps) * gamma.reshape(shape) \
        + beta.reshape(shape)
    return out, mu, var


def apply_train(cfg: ModelConfig, tp: Dict[str, Any], x: jax.Array):
    """Training forward: logits + per-BN batch statistics (for folding).

    `tp` is the training pytree {layer: {w}, bn_layer: {gamma, beta}}.
    """
    stats: Dict[str, Any] = {}
    h = x
    for s in cfg.conv_specs:
        w = binweight(tp[s.name]["w"])
        if s.binarized:
            # Binarize, then pad with +1 explicitly: inference binarizes
            # the zero-padded column matrix and sign(0) = +1, so training
            # must see the same padding values (train/infer consistency).
            a = binact(h)
            if s.pad:
                a = jnp.pad(a, ((0, 0), (0, 0), (s.pad, s.pad),
                                (s.pad, s.pad)), constant_values=1.0)
            pad = 0
        else:
            a, pad = h, s.pad
        h = lax.conv_general_dilated(
            a, w, window_strides=(s.stride, s.stride),
            padding=[(pad, pad), (pad, pad)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if s.pool:
            h = maxpool2(h)
        bn = tp[f"bn_{s.name}"]
        h, mu, var = batchnorm_train(h, bn["gamma"], bn["beta"], (0, 2, 3))
        stats[f"bn_{s.name}"] = (mu, var)
    h = h.reshape(h.shape[0], -1)
    for s in cfg.fc_specs:
        a = binact(h)
        h = jnp.dot(a, binweight(tp[s.name]["w"]).T)
        bn = tp[f"bn_{s.name}"]
        h, mu, var = batchnorm_train(h, bn["gamma"], bn["beta"], (0,))
        stats[f"bn_{s.name}"] = (mu, var)
    return h, stats


def init_train_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Training pytree: latent float weights + BN (gamma, beta)."""
    rng = np.random.default_rng(seed)
    tp: Dict[str, Any] = {}
    for s in cfg.conv_specs:
        tp[s.name] = {"w": jnp.asarray(
            rng.normal(0, 1.0 / np.sqrt(s.k),
                       size=(s.cout, s.cin, s.ksize, s.ksize))
            .astype(np.float32))}
        tp[f"bn_{s.name}"] = {"gamma": jnp.ones((s.cout,), jnp.float32),
                              "beta": jnp.zeros((s.cout,), jnp.float32)}
    for s in cfg.fc_specs:
        tp[s.name] = {"w": jnp.asarray(
            rng.normal(0, 1.0 / np.sqrt(s.din), size=(s.dout, s.din))
            .astype(np.float32))}
        tp[f"bn_{s.name}"] = {"gamma": jnp.ones((s.dout,), jnp.float32),
                              "beta": jnp.zeros((s.dout,), jnp.float32)}
    return tp


def fold_bn(tp: Dict[str, Any], running: Dict[str, Any],
            eps: float = 1e-4) -> Dict[str, Any]:
    """Training pytree + running (mu, var) -> inference float pytree.

    BN(y) = gamma*(y-mu)/sqrt(var+eps) + beta  ==  a*y + b  with
    a = gamma/sqrt(var+eps), b = beta - a*mu.  Weights are sign-binarized.
    """
    params: Dict[str, Any] = {}
    for k, v in tp.items():
        if "w" in v:
            params[k] = {"w": sign(v["w"])}
        else:
            mu, var = running[k]
            a = v["gamma"] / jnp.sqrt(var + eps)
            params[k] = {"a": a, "b": v["beta"] - a * mu}
    return params
