"""Training-loop tests: STE learning signal, BN folding, BKW1 round-trip."""

import numpy as np
import jax.numpy as jnp

from compile import dataset, model, train

TINY = model.ModelConfig(scale=0.0625)


def test_adam_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    import jax
    for _ in range(200):
        g = jax.grad(lambda p: (p["w"] ** 2).sum())(params)
        params, opt = train.adam_update(params, g, opt, lr=0.1)
    assert np.abs(np.asarray(params["w"])).max() < 0.1


def test_training_reduces_loss():
    tp, running, hist = train.train(TINY, steps=60, batch=32, train_n=320,
                                    log_every=0, seed=1)
    first = np.mean([h[1] for h in hist[:10]])
    last = np.mean([h[1] for h in hist[-10:]])
    assert last < first, (first, last)


def test_fold_bn_matches_batchnorm():
    gamma = jnp.asarray([2.0, 0.5])
    beta = jnp.asarray([1.0, -1.0])
    mu = jnp.asarray([0.3, -0.2])
    var = jnp.asarray([4.0, 0.25])
    tp = {"bn_x": {"gamma": gamma, "beta": beta}}
    folded = model.fold_bn(tp, {"bn_x": (mu, var)}, eps=0.0)
    y = jnp.asarray([1.0, 1.0])
    want = gamma * (y - mu) / jnp.sqrt(var) + beta
    got = folded["bn_x"]["a"] * y + folded["bn_x"]["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_fold_bn_binarizes_weights():
    tp = {"conv1": {"w": jnp.asarray([[0.3, -0.7], [0.0, 2.0]])}}
    folded = model.fold_bn(tp, {})
    assert np.asarray(folded["conv1"]["w"]).tolist() == [[1, -1], [1, 1]]


def test_bkw_roundtrip(tmp_path):
    params = model.binarize_params(model.init_params(TINY, seed=4))
    p = str(tmp_path / "w.bkw")
    train.save_bkw(p, TINY, params)
    raw = train.load_bkw(p)
    assert (raw["meta.widths"]
            == np.asarray(TINY.widths + TINY.fc_widths, np.uint32)).all()
    back = train.bkw_to_pytree(TINY, raw)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(1, 3, 32, 32)).astype(np.float32))
    a = model.apply_inference(TINY, params, x, "optimized")
    b = model.apply_inference(TINY, back, x, "optimized")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bkw_labels_round_trip(tmp_path):
    params = model.binarize_params(model.init_params(TINY, seed=4))
    p = str(tmp_path / "w.bkw")
    # Default export carries the ShapeSet-10 labels, trailing — so the
    # tensor reader is oblivious to them.
    train.save_bkw(p, TINY, params)
    assert train.load_bkw_labels(p) == dataset.CLASS_NAMES
    assert "meta.widths" in train.load_bkw(p)
    # Explicit [] writes a label-less file (numeric labels at serve
    # time).
    train.save_bkw(p, TINY, params, labels=[])
    assert train.load_bkw_labels(p) is None


def test_clip_latents_only_touches_matrices():
    tp = {"conv": {"w": jnp.asarray([[3.0, -3.0]])},
          "bn": {"gamma": jnp.asarray([5.0]), "beta": jnp.asarray([-5.0])}}
    out = train.clip_latents(tp)
    assert np.asarray(out["conv"]["w"]).tolist() == [[1.0, -1.0]]
    assert float(out["bn"]["gamma"][0]) == 5.0  # 1-D BN params not clipped


def test_eval_accuracy_untrained_near_chance():
    params = model.binarize_params(model.init_params(TINY, seed=0))
    imgs, labels = dataset.make_split(128, seed=11)
    acc = train.eval_accuracy(TINY, params, imgs, labels, batch=64)
    assert 0.0 <= acc <= 0.45  # untrained: near 10% chance
