"""Fused binarized conv (Figure 3) and the three Table-2 conv arms.

The three arms (xnor / control / optimized) must produce IDENTICAL
outputs — they compute the same binarized network with different kernels.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r "
           "python/requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from compile.kernels import binconv, pack, ref


def _rand(seed, *shape):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=shape).astype(np.float32))


def _packed_weights(w):
    d = w.shape[0]
    return pack.pack_rows(ref.sign(w.reshape(d, -1)))


@pytest.mark.parametrize("stride,pad,kh", [(1, 0, 3), (1, 1, 3), (2, 1, 3),
                                           (1, 0, 1), (2, 2, 5)])
def test_binconv_matches_oracle(stride, pad, kh):
    x = _rand(10 * stride + pad, 2, 3, 11, 11)
    w = _rand(20 * stride + pad, 4, 3, kh, kh)
    want = np.asarray(ref.binconv2d_ref(x, w, stride, pad))
    got = np.asarray(binconv.binconv2d(x, _packed_weights(w),
                                       (4, 3, kh, kh), stride, pad))
    assert (got == want).all()


@settings(deadline=None, max_examples=15)
@given(b=st.integers(1, 3), c=st.integers(1, 5), d=st.integers(1, 6),
       hw=st.integers(4, 12))
def test_three_arms_identical(b, c, d, hw):
    """xnor == control == optimized, elementwise exact."""
    seed = b * 1000 + c * 100 + d * 10 + hw
    x = _rand(seed, b, c, hw, hw)
    w = _rand(seed + 1, d, c, 3, 3)
    o_xnor = np.asarray(binconv.binconv2d(x, _packed_weights(w),
                                          (d, c, 3, 3), 1, 1))
    o_ctrl = np.asarray(binconv.conv2d_control(x, w, 1, 1))
    o_opt = np.asarray(binconv.conv2d_optimized(x, w, 1, 1))
    assert (o_xnor == o_ctrl).all()
    assert (o_xnor == o_opt).all()


def test_im2col_matches_ref():
    x = _rand(5, 2, 3, 9, 9)
    a = np.asarray(binconv.im2col(x, 3, 3, 1, 1))
    b = np.asarray(ref.im2col_ref(x, 3, 3, 1, 1))
    np.testing.assert_allclose(a, b)


def test_im2col_strided_matches_ref():
    x = _rand(6, 1, 4, 10, 12)
    a = np.asarray(binconv.im2col(x, 5, 3, 2, 2))
    b = np.asarray(ref.im2col_ref(x, 5, 3, 2, 2))
    np.testing.assert_allclose(a, b)


def test_binconv_output_integrality():
    """Binarized conv outputs are exact signed integers with K's parity."""
    x = _rand(7, 1, 3, 8, 8)
    w = _rand(8, 2, 3, 3, 3)
    out = np.asarray(binconv.binconv2d(x, _packed_weights(w),
                                       (2, 3, 3, 3), 1, 0))
    k = 3 * 3 * 3
    assert (out == np.round(out)).all()
    assert np.abs(out).max() <= k
    assert ((out.astype(np.int64) % 2) == (k % 2)).all()
