"""Pallas encode kernels vs the pure-jnp oracle (hypothesis shape sweep)."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r "
           "python/requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, ref


def _rand(seed, *shape):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=shape).astype(np.float32))


@settings(deadline=None, max_examples=25)
@given(d=st.integers(1, 70), k=st.integers(1, 200))
def test_pack_rows_matches_ref(d, k):
    w = _rand(d * 1000 + k, d, k)
    got = np.asarray(pack.pack_rows(w, block_rows=16, block_words=2))
    want = np.asarray(ref.pack_rows_ref(w))
    assert got.dtype == np.uint32
    assert (got == want).all()


@settings(deadline=None, max_examples=25)
@given(k=st.integers(1, 200), n=st.integers(1, 70))
def test_pack_cols_matches_ref(k, n):
    x = _rand(k * 1000 + n, k, n)
    got = np.asarray(pack.pack_cols(x, block_words=2, block_cols=16))
    want = np.asarray(ref.pack_cols_ref(x))
    assert (got == want).all()


@settings(deadline=None, max_examples=10)
@given(br=st.sampled_from([1, 3, 16, 64]), bw=st.sampled_from([1, 2, 8]))
def test_pack_rows_block_size_invariance(br, bw):
    """Output must not depend on the tile decomposition."""
    w = _rand(7, 33, 97)
    got = np.asarray(pack.pack_rows(w, block_rows=br, block_words=bw))
    want = np.asarray(ref.pack_rows_ref(w))
    assert (got == want).all()


def test_pack_zero_is_plus_one():
    """sign(0) = +1 must hold through the Pallas path too."""
    w = jnp.zeros((2, 40))
    got = np.asarray(pack.pack_rows(w))
    # first word all ones; second word: 8 real bits set, 24 pad bits 0
    assert got[0, 0] == 0xFFFFFFFF
    assert got[0, 1] == 0x000000FF


def test_pack_defaults_large():
    """Default block sizes on a layer-sized matrix."""
    w = _rand(99, 512, 4608)
    assert (np.asarray(pack.pack_rows(w))
            == np.asarray(ref.pack_rows_ref(w))).all()
    x = _rand(100, 4608, 256)
    assert (np.asarray(pack.pack_cols(x))
            == np.asarray(ref.pack_cols_ref(x))).all()
