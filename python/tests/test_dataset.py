"""ShapeSet-10 generator + BKD1 round-trip tests."""

import numpy as np
import pytest

from compile import dataset


def test_make_image_shapes_and_range():
    rng = np.random.default_rng(0)
    for label in range(dataset.NUM_CLASSES):
        img = dataset.make_image(label, rng)
        assert img.shape == (32, 32, 3)
        assert img.dtype == np.uint8


def test_split_balanced_and_deterministic():
    imgs1, labels1 = dataset.make_split(100, seed=5)
    imgs2, labels2 = dataset.make_split(100, seed=5)
    np.testing.assert_array_equal(imgs1, imgs2)
    np.testing.assert_array_equal(labels1, labels2)
    counts = np.bincount(labels1, minlength=10)
    assert counts.min() == counts.max() == 10


def test_split_seed_sensitivity():
    imgs1, _ = dataset.make_split(20, seed=1)
    imgs2, _ = dataset.make_split(20, seed=2)
    assert (imgs1 != imgs2).any()


def test_normalize():
    imgs = np.zeros((2, 32, 32, 3), np.uint8)
    imgs[0] = 255
    x = dataset.normalize(imgs)
    assert x.shape == (2, 3, 32, 32)
    assert x.dtype == np.float32
    np.testing.assert_allclose(x[0], 1.0)
    np.testing.assert_allclose(x[1], -1.0)


def test_bkd_roundtrip(tmp_path):
    imgs, labels = dataset.make_split(30, seed=9)
    p = str(tmp_path / "ds.bin")
    dataset.save_bkd(p, imgs, labels)
    imgs2, labels2 = dataset.load_bkd(p)
    np.testing.assert_array_equal(imgs, imgs2)
    np.testing.assert_array_equal(labels, labels2)


def test_bkd_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE" + b"\0" * 16)
    with pytest.raises(AssertionError):
        dataset.load_bkd(str(p))


def test_classes_are_visually_distinct():
    """Mean images of different classes must differ substantially."""
    imgs, labels = dataset.make_split(200, seed=3)
    means = np.stack([imgs[labels == c].mean(axis=0).mean(axis=-1)
                      for c in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(means[a] - means[b]).mean() > 1.0, (a, b)
