"""Oracle self-consistency: the ref.py chain of trust.

These tests pin the *oracles* themselves against mathematical ground
truth (plain float matmul on {-1,+1} values, lax.conv), including the
paper's Table 1 truth table, so the Pallas-vs-ref tests elsewhere are
anchored to something real.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r "
           "python/requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _randf(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Table 1: xnor(encodings) == multiply(values)
# ---------------------------------------------------------------------------

def test_table1_truth_table():
    """Exhaustive Table 1: for all 4 bit pairs, xnor == +-1 multiply."""
    for ea in (0, 1):
        for eb in (0, 1):
            va, vb = 2 * ea - 1, 2 * eb - 1
            xnor = 1 ^ (ea ^ eb)
            assert 2 * xnor - 1 == va * vb


def test_table1_wordwise():
    """Word-level Table 1: 2*popcount(~(a^b)) - 32 == dot of +-1 vectors."""
    for _ in range(64):
        a_bits = RNG.integers(0, 2, size=32)
        b_bits = RNG.integers(0, 2, size=32)
        a = int(sum(int(b) << i for i, b in enumerate(a_bits)))
        b = int(sum(int(b) << i for i, b in enumerate(b_bits)))
        popc = bin(~(a ^ b) & 0xFFFFFFFF).count("1")
        dot = int(np.dot(2 * a_bits - 1, 2 * b_bits - 1))
        assert 2 * popc - 32 == dot


# ---------------------------------------------------------------------------
# sign / pack / unpack
# ---------------------------------------------------------------------------

def test_sign_zero_maps_to_plus_one():
    x = jnp.asarray([-2.0, -0.0, 0.0, 0.5])
    out = np.asarray(ref.sign(x))
    # -0.0 >= 0 is True in IEEE, so both zeros binarize to +1.
    assert out.tolist() == [-1.0, 1.0, 1.0, 1.0]


@settings(deadline=None, max_examples=30)
@given(d=st.integers(1, 40), k=st.integers(1, 130))
def test_pack_rows_roundtrip(d, k):
    w = jnp.asarray(np.random.default_rng(d * 1000 + k)
                    .normal(size=(d, k)).astype(np.float32))
    wp = ref.pack_rows_ref(w)
    assert wp.dtype == jnp.uint32
    assert wp.shape == (d, ref.padded_k(k) // 32)
    back = ref.unpack_rows_ref(wp, k)
    assert (back == ref.sign(w)).all()


@settings(deadline=None, max_examples=30)
@given(k=st.integers(1, 130), n=st.integers(1, 40))
def test_pack_cols_roundtrip(k, n):
    x = jnp.asarray(np.random.default_rng(k * 1000 + n)
                    .normal(size=(k, n)).astype(np.float32))
    xp = ref.pack_cols_ref(x)
    assert xp.shape == (ref.padded_k(k) // 32, n)
    back = ref.unpack_cols_ref(xp, k)
    assert (back == ref.sign(x)).all()


def test_pack_bit_order_little_endian():
    """Bit i of word w encodes element w*32+i; element 0 is bit 0."""
    w = -jnp.ones((1, 64))
    w = w.at[0, 0].set(1.0)    # word 0, bit 0
    w = w.at[0, 33].set(1.0)   # word 1, bit 1
    wp = np.asarray(ref.pack_rows_ref(w))
    assert wp[0, 0] == 1
    assert wp[0, 1] == 2


def test_pack_row_col_transpose_consistency():
    """pack_cols(x) == pack_rows(x.T).T for any x."""
    x = _randf(70, 9)
    a = np.asarray(ref.pack_cols_ref(x))
    b = np.asarray(ref.pack_rows_ref(x.T)).T
    assert (a == b).all()


# ---------------------------------------------------------------------------
# packed gemm oracle vs value-domain ground truth
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=30)
@given(d=st.integers(1, 24), k=st.integers(1, 100), n=st.integers(1, 24))
def test_xnor_gemm_packed_ref_exact(d, k, n):
    rng = np.random.default_rng(d * 10000 + k * 100 + n)
    w = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    truth = np.asarray(ref.xnor_gemm_value_ref(w, x)).astype(np.int32)
    got = np.asarray(ref.xnor_gemm_packed_ref(
        ref.pack_rows_ref(w), ref.pack_cols_ref(x), k))
    assert (got == truth).all()


def test_xnor_gemm_extremes():
    """All +1 x all +1 -> K; all +1 x all -1 -> -K (exercises correction)."""
    for k in (1, 31, 32, 33, 95):
        ones = jnp.ones((2, k))
        mones = -jnp.ones((k, 3))
        got = np.asarray(ref.xnor_gemm_packed_ref(
            ref.pack_rows_ref(ones), ref.pack_cols_ref(mones), k))
        assert (got == -k).all(), k
        got2 = np.asarray(ref.xnor_gemm_packed_ref(
            ref.pack_rows_ref(ones), ref.pack_cols_ref(-mones), k))
        assert (got2 == k).all(), k


# ---------------------------------------------------------------------------
# im2col / conv graphs (Figures 1-3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,pad,kh", [(1, 0, 3), (1, 1, 3), (2, 1, 3),
                                           (1, 0, 1), (2, 0, 5), (1, 2, 5)])
def test_im2col_conv_equiv(stride, pad, kh):
    """Figure-2 graph (im2col+gemm) == direct lax.conv."""
    x = _randf(2, 3, 12, 12)
    w = _randf(4, 3, kh, kh)
    a = np.asarray(ref.conv2d_im2col_ref(x, w, stride, pad))
    b = np.asarray(ref.conv2d_ref(x, w, stride, pad))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_im2col_shape():
    x = _randf(2, 3, 8, 10)
    cols = ref.im2col_ref(x, 3, 3, stride=1, pad=1)
    assert cols.shape == (3 * 3 * 3, 2 * 8 * 10)


@pytest.mark.parametrize("stride,pad", [(1, 0), (1, 1), (2, 1)])
def test_binconv_ref_is_binarized_conv(stride, pad):
    """Figure-3 oracle == lax.conv on sign(x), sign(w) (pad in sign domain)."""
    x = _randf(1, 2, 9, 9)
    w = _randf(3, 2, 3, 3)
    a = np.asarray(ref.binconv2d_ref(x, w, stride, pad))
    xb = ref.sign(x)
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, 0), (pad, pad), (pad, pad)),
                     constant_values=1.0)
    b = np.asarray(ref.conv2d_ref(xb, ref.sign(w), stride, 0))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
