"""Pallas xnor-bitcount gemm vs ground truth — the CORE correctness signal.

The kernel must be EXACTLY equal (integer arithmetic) to the float matmul
of the underlying {-1,+1} matrices, for any shape, any padding residue
K % 32, and any tile decomposition.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r "
           "python/requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, ref
from compile.kernels.xnor_gemm import xnor_gemm


def _case(seed, d, k, n):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    truth = np.asarray(ref.xnor_gemm_value_ref(w, x)).astype(np.int32)
    return pack.pack_rows(w), pack.pack_cols(x), truth


@settings(deadline=None, max_examples=30)
@given(d=st.integers(1, 48), k=st.integers(1, 200), n=st.integers(1, 48))
def test_xnor_gemm_exact_any_shape(d, k, n):
    wp, xp, truth = _case(d * 100000 + k * 100 + n, d, k, n)
    got = np.asarray(xnor_gemm(wp, xp, k, block_d=16, block_n=16, block_k=2))
    assert got.dtype == np.int32
    assert (got == truth).all()


@pytest.mark.parametrize("k", [1, 31, 32, 33, 63, 64, 65, 96, 127])
def test_xnor_gemm_padding_residues(k):
    """Every K % 32 residue class near word boundaries."""
    wp, xp, truth = _case(k, 5, k, 7)
    got = np.asarray(xnor_gemm(wp, xp, k, block_d=4, block_n=4, block_k=1))
    assert (got == truth).all()


@pytest.mark.parametrize("bd,bn,bk", [(1, 1, 1), (3, 5, 2), (16, 16, 4),
                                      (128, 128, 8), (64, 256, 16)])
def test_xnor_gemm_block_invariance(bd, bn, bk):
    """Result must not depend on the tile decomposition."""
    wp, xp, truth = _case(42, 33, 170, 29)
    got = np.asarray(xnor_gemm(wp, xp, 170, block_d=bd, block_n=bn,
                               block_k=bk))
    assert (got == truth).all()


def test_xnor_gemm_layer_shape():
    """A real BNN layer shape: D=128, K=3*3*128=1152, N=an 8x8 feature map."""
    wp, xp, truth = _case(7, 128, 1152, 64)
    got = np.asarray(xnor_gemm(wp, xp, 1152))
    assert (got == truth).all()


def test_xnor_gemm_identity_rows():
    """w row == x col -> output K (perfect correlation); negated -> -K."""
    k = 70
    v = jnp.asarray(np.random.default_rng(3).normal(size=(1, k))
                    .astype(np.float32))
    wp = pack.pack_rows(v)
    xp = pack.pack_cols(jnp.stack([v[0], -v[0]], axis=1))
    got = np.asarray(xnor_gemm(wp, xp, k, block_d=1, block_n=1, block_k=1))
    assert got[0, 0] == k
    assert got[0, 1] == -k


def test_xnor_gemm_mismatched_kw_raises():
    wp = jnp.zeros((2, 3), jnp.uint32)
    xp = jnp.zeros((4, 2), jnp.uint32)
    with pytest.raises(AssertionError):
        xnor_gemm(wp, xp, 96)


def test_xnor_gemm_output_range():
    """Every output element lies in [-K, K] with K's parity."""
    k = 77
    wp, xp, _ = _case(11, 9, k, 9)
    got = np.asarray(xnor_gemm(wp, xp, k, block_d=8, block_n=8, block_k=2))
    assert got.min() >= -k and got.max() <= k
    assert ((got % 2) == (k % 2)).all()  # dot of k odd/even +-1 terms
