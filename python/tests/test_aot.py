"""AOT lowering smoke tests: descriptors, HLO text, manifest contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


TINY = model.ModelConfig(scale=0.0625)


def test_input_descriptors_cover_all_args_in_order():
    params = model.binarize_params(model.init_params(TINY, seed=0))
    packed = model.pack_params(TINY, params)
    x = jnp.zeros((1, 3, 32, 32), jnp.float32)
    descs = aot.input_descriptors(TINY, packed, x)
    leaves = jax.tree_util.tree_flatten((packed, x))[0]
    assert len(descs) == len(leaves)
    # shapes/dtypes match the actual flattened leaves, in order
    for d, leaf in zip(descs, leaves):
        assert tuple(d["shape"]) == leaf.shape, d["name"]
        assert d["dtype"] == ("u32" if leaf.dtype == jnp.uint32 else "f32")
    # exactly one image input, and it is the LAST flattened leaf
    kinds = [d["kind"] for d in descs]
    assert kinds.count("image") == 1
    assert kinds[-1] == "image"
    # every packed weight records its source + logical k
    for d in descs:
        if d["transform"] == "pack_rows":
            assert d["source"].endswith(".w")
            assert d["logical_k"] > 0
            assert d["shape"][1] == (d["logical_k"] + 31) // 32


def test_lower_model_writes_parsable_hlo(tmp_path):
    out = str(tmp_path / "m.hlo.txt")
    descs = aot.lower_model(TINY, "optimized", 1, out)
    text = open(out).read()
    assert text.startswith("HloModule")
    assert len(descs) >= 10
    # parameter count in the HLO matches the descriptor count
    assert text.count("parameter(") >= len(descs)


def test_lower_kernel_all_variants(tmp_path):
    for kernel in ["xnor", "control", "optimized"]:
        out = str(tmp_path / f"{kernel}.hlo.txt")
        info = aot.lower_kernel(kernel, 8, 70, 6, out)
        assert info["kernel"] == kernel
        assert open(out).read().startswith("HloModule")
        if kernel == "xnor":
            assert info["inputs"][0]["dtype"] == "u32"
            assert info["inputs"][0]["shape"] == [8, 3]


def test_quick_build_manifest_contract(tmp_path):
    """A full (quick) build emits a manifest rust can rely on."""
    out = str(tmp_path / "art")
    aot.build(out, quick=True, log=lambda *_: None)
    m = json.load(open(os.path.join(out, "manifest.json")))
    assert m["format"] == 1
    assert {x["variant"] for x in m["models"]} == {"xnor", "control",
                                                   "optimized"}
    for entry in m["models"]:
        assert os.path.exists(os.path.join(out, entry["file"]))
        assert entry["output"]["shape"][1] == 10
        assert entry["inputs"][-1]["kind"] == "image"
    assert os.path.exists(os.path.join(out, m["weights"]["small"]["file"]))
    assert os.path.exists(os.path.join(out, m["datasets"]["test"]["file"]))
    # dataset round-trips
    from compile import dataset
    imgs, labels = dataset.load_bkd(
        os.path.join(out, m["datasets"]["test"]["file"]))
    assert imgs.shape[0] == m["datasets"]["test"]["count"]
    assert labels.max() <= 9
