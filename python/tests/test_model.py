"""L2 model tests: config, shapes, and the cross-variant logit invariant."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model

TINY = model.ModelConfig(scale=0.0625)


def _x(seed, b=2):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=(b, 3, 32, 32)).astype(np.float32))


def test_config_full_matches_paper():
    cfg = model.ModelConfig(scale=1.0)
    assert cfg.widths == [128, 128, 256, 256, 512, 512]
    assert cfg.fc_widths == [1024, 1024, 10]
    specs = cfg.conv_specs
    assert [s.pool for s in specs] == [False, True] * 3
    assert specs[0].binarized is False
    assert all(s.binarized for s in specs[1:])
    assert cfg.fc_specs[0].din == 512 * 4 * 4
    # Courbariaux's CIFAR-10 ConvNet is ~14M parameters
    assert 13_000_000 < cfg.param_count() < 16_000_000


def test_config_scaling():
    cfg = model.ModelConfig(scale=0.25)
    assert cfg.widths == [32, 32, 64, 64, 128, 128]
    assert cfg.fc_widths == [256, 256, 10]


def test_inference_shapes():
    params = model.binarize_params(model.init_params(TINY, seed=0))
    logits = model.apply_inference(TINY, params, _x(0, b=3), "optimized")
    assert logits.shape == (3, 10)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_variant_equivalence_exact(seed):
    """The paper's premise: all three kernels compute the SAME network."""
    params = model.binarize_params(model.init_params(TINY, seed=seed))
    packed = model.pack_params(TINY, params)
    x = _x(seed)
    lo = np.asarray(model.apply_inference(TINY, params, x, "optimized"))
    lc = np.asarray(model.apply_inference(TINY, params, x, "control"))
    lx = np.asarray(model.apply_inference(TINY, packed, x, "xnor"))
    np.testing.assert_array_equal(lo, lc)
    np.testing.assert_array_equal(lo, lx)


def test_variant_equivalence_with_bn():
    """Equivalence must survive non-identity folded BN affines."""
    rng = np.random.default_rng(7)
    params = model.binarize_params(model.init_params(TINY, seed=7))
    for k, v in params.items():
        if "a" in v:
            v["a"] = jnp.asarray(rng.uniform(0.5, 2.0,
                                             v["a"].shape).astype(np.float32))
            v["b"] = jnp.asarray(rng.normal(0, 1,
                                            v["b"].shape).astype(np.float32))
    packed = model.pack_params(TINY, params)
    x = _x(7)
    lo = np.asarray(model.apply_inference(TINY, params, x, "optimized"))
    lx = np.asarray(model.apply_inference(TINY, packed, x, "xnor"))
    np.testing.assert_allclose(lo, lx, rtol=1e-5, atol=1e-5)


def test_maxpool2():
    h = jnp.asarray(np.arange(16, dtype=np.float32).reshape(1, 1, 4, 4))
    out = np.asarray(model.maxpool2(h))
    assert out.shape == (1, 1, 2, 2)
    assert out.reshape(-1).tolist() == [5, 7, 13, 15]


def test_binact_forward_and_gradient():
    import jax
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    y = model.binact(x)
    assert np.asarray(y).tolist() == [-1, -1, 1, 1, 1]
    g = jax.grad(lambda v: model.binact(v).sum())(x)
    # Htanh STE: gradient 1 inside [-1, 1], 0 outside
    assert np.asarray(g).tolist() == [0, 1, 1, 1, 0]


def test_binweight_gradient_is_identity():
    import jax
    w = jnp.asarray([-2.0, 0.3, 1.5])
    g = jax.grad(lambda v: (model.binweight(v) * 3.0).sum())(w)
    assert np.asarray(g).tolist() == [3, 3, 3]


def test_pack_params_structure():
    params = model.binarize_params(model.init_params(TINY, seed=0))
    packed = model.pack_params(TINY, params)
    assert "w" in packed["conv1"] and "wp" not in packed["conv1"]
    for name in ["conv2", "conv3", "fc1", "fc3"]:
        assert "wp" in packed[name]
        assert packed[name]["wp"].dtype == jnp.uint32
    s = TINY.conv_specs[1]
    assert packed["conv2"]["wp"].shape == (s.cout, (s.k + 31) // 32)
