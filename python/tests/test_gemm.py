"""Control-group Pallas f32 gemm vs jnp matmul."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r "
           "python/requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm import gemm_f32


def _rand(seed, *shape):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=shape).astype(np.float32))


@settings(deadline=None, max_examples=25)
@given(d=st.integers(1, 40), k=st.integers(1, 150), n=st.integers(1, 40))
def test_gemm_matches_matmul(d, k, n):
    a = _rand(d * 100000 + k * 100 + n, d, k)
    b = _rand(d * 100000 + k * 100 + n + 1, k, n)
    got = np.asarray(gemm_f32(a, b, block_d=16, block_n=16, block_k=32))
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bd,bn,bk", [(1, 1, 1), (7, 5, 13), (128, 128, 256),
                                      (64, 32, 64)])
def test_gemm_block_invariance(bd, bn, bk):
    a = _rand(1, 33, 170)
    b = _rand(2, 170, 29)
    got = np.asarray(gemm_f32(a, b, block_d=bd, block_n=bn, block_k=bk))
    np.testing.assert_allclose(got, np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_gemm_exact_on_binary_values():
    """On {-1,+1} operands the float gemm is exact (integers < 2^24)."""
    rng = np.random.default_rng(9)
    a = np.where(rng.normal(size=(12, 100)) >= 0, 1.0, -1.0).astype(np.float32)
    b = np.where(rng.normal(size=(100, 11)) >= 0, 1.0, -1.0).astype(np.float32)
    got = np.asarray(gemm_f32(jnp.asarray(a), jnp.asarray(b),
                              block_d=8, block_n=8, block_k=32))
    assert (got == a @ b).all()


def test_gemm_zero_k_block_padding():
    """K smaller than the block: padding must not pollute the result."""
    a = _rand(5, 3, 2)
    b = _rand(6, 2, 3)
    got = np.asarray(gemm_f32(a, b))
    np.testing.assert_allclose(got, np.asarray(a) @ np.asarray(b),
                               rtol=1e-5, atol=1e-5)
