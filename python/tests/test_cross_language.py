"""Cross-language convention pins.

The rust engine re-implements bit packing, normalization and the BKW1/
BKD1 formats.  These tests pin the python side of each convention to
golden values that rust/src/bitops/pack.rs::tests::golden_cross_language
and rust/src/data/bkd.rs pin identically — if either side drifts, one of
the twins fails.
"""

import numpy as np
import jax.numpy as jnp

from compile import dataset
from compile.kernels import ref


def test_pack_golden_matches_rust():
    """Same case as rust bitops::pack::tests::golden_cross_language."""
    vals = np.sin(np.arange(40, dtype=np.float32) * 0.7)
    p = np.asarray(ref.pack_rows_ref(jnp.asarray(vals[None, :])))
    want0 = 0
    want1 = 0
    for i, v in enumerate(vals):
        if v >= 0:
            if i < 32:
                want0 |= 1 << i
            else:
                want1 |= 1 << (i - 32)
    assert p.tolist() == [[want0, want1]]


def test_pack_bit_order_golden():
    """Element 0 -> bit 0 word 0; element 33 -> bit 1 word 1 (rust twin:
    bit_order_little_endian)."""
    row = -np.ones(64, np.float32)
    row[0] = 1.0
    row[33] = 1.0
    p = np.asarray(ref.pack_rows_ref(jnp.asarray(row[None, :])))
    assert p.tolist() == [[1, 2]]


def test_pack_padding_golden():
    """40 ones -> [0xFFFFFFFF, 0xFF] (rust twin: padding_bits_are_zero)."""
    p = np.asarray(ref.pack_rows_ref(jnp.ones((1, 40))))
    assert p.tolist() == [[0xFFFFFFFF, 0xFF]]


def test_normalization_golden():
    """255 -> +1.0, 0 -> -1.0, 128 -> 128/127.5 - 1 (rust twin:
    data::bkd::tests::normalize_layout_and_range)."""
    imgs = np.zeros((1, 32, 32, 3), np.uint8)
    imgs[0, 0, 0, 0] = 255
    imgs[0, 0, 0, 1] = 128
    x = dataset.normalize(imgs)
    assert x[0, 0, 0, 0] == 1.0
    assert abs(x[0, 1, 0, 0] - (128 / 127.5 - 1.0)) < 1e-6
    assert x[0, 2, 0, 0] == -1.0


def test_xnor_formula_golden():
    """One fixed word pair, the Sec. 3.2 formula by hand (rust twin:
    xnor::tests::table1_word_identity)."""
    a = np.uint32(0xAAAAAAAA)
    b = np.uint32(0x55555555)
    # xnor = ~(a ^ b) = ~0xFFFFFFFF = 0 -> popcount 0 -> 2*0 - 32 = -32
    assert bin(~(int(a) ^ int(b)) & 0xFFFFFFFF).count("1") == 0
    wp = jnp.asarray([[a]], jnp.uint32)
    xp = jnp.asarray([[b]], jnp.uint32)
    out = np.asarray(ref.xnor_gemm_packed_ref(wp, xp, 32))
    assert out.tolist() == [[-32]]
