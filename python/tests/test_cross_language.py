"""Cross-language convention pins.

The rust engine re-implements bit packing, normalization and the BKW1/
BKD1 formats.  These tests pin the python side of each convention to
golden values that rust/src/bitops/pack.rs::tests::golden_cross_language
and rust/src/data/bkd.rs pin identically — if either side drifts, one of
the twins fails.

The per-scheme fixture tests at the bottom go further: for every
quantization scheme they regenerate a tiny integer-exact BKW2 model +
expected logits and compare byte-for-byte against the checked-in
goldens under rust/tests/fixtures/, which the rust side
(tests/scheme_conformance.rs) loads and pins bit-identical through
every kernel arm.  Run this file as a script to (re)write the goldens.
"""

import io
import pathlib
import struct

import numpy as np
import jax.numpy as jnp
import pytest

from compile import dataset, train
from compile.kernels import ref


def test_pack_golden_matches_rust():
    """Same case as rust bitops::pack::tests::golden_cross_language."""
    vals = np.sin(np.arange(40, dtype=np.float32) * 0.7)
    p = np.asarray(ref.pack_rows_ref(jnp.asarray(vals[None, :])))
    want0 = 0
    want1 = 0
    for i, v in enumerate(vals):
        if v >= 0:
            if i < 32:
                want0 |= 1 << i
            else:
                want1 |= 1 << (i - 32)
    assert p.tolist() == [[want0, want1]]


def test_pack_bit_order_golden():
    """Element 0 -> bit 0 word 0; element 33 -> bit 1 word 1 (rust twin:
    bit_order_little_endian)."""
    row = -np.ones(64, np.float32)
    row[0] = 1.0
    row[33] = 1.0
    p = np.asarray(ref.pack_rows_ref(jnp.asarray(row[None, :])))
    assert p.tolist() == [[1, 2]]


def test_pack_padding_golden():
    """40 ones -> [0xFFFFFFFF, 0xFF] (rust twin: padding_bits_are_zero)."""
    p = np.asarray(ref.pack_rows_ref(jnp.ones((1, 40))))
    assert p.tolist() == [[0xFFFFFFFF, 0xFF]]


def test_normalization_golden():
    """255 -> +1.0, 0 -> -1.0, 128 -> 128/127.5 - 1 (rust twin:
    data::bkd::tests::normalize_layout_and_range)."""
    imgs = np.zeros((1, 32, 32, 3), np.uint8)
    imgs[0, 0, 0, 0] = 255
    imgs[0, 0, 0, 1] = 128
    x = dataset.normalize(imgs)
    assert x[0, 0, 0, 0] == 1.0
    assert abs(x[0, 1, 0, 0] - (128 / 127.5 - 1.0)) < 1e-6
    assert x[0, 2, 0, 0] == -1.0


def test_xnor_formula_golden():
    """One fixed word pair, the Sec. 3.2 formula by hand (rust twin:
    xnor::tests::table1_word_identity)."""
    a = np.uint32(0xAAAAAAAA)
    b = np.uint32(0x55555555)
    # xnor = ~(a ^ b) = ~0xFFFFFFFF = 0 -> popcount 0 -> 2*0 - 32 = -32
    assert bin(~(int(a) ^ int(b)) & 0xFFFFFFFF).count("1") == 0
    wp = jnp.asarray([[a]], jnp.uint32)
    xp = jnp.asarray([[b]], jnp.uint32)
    out = np.asarray(ref.xnor_gemm_packed_ref(wp, xp, 32))
    assert out.tolist() == [[-32]]


# ---------------------------------------------------------------------------
# per-scheme BKW2 fixtures (rust twin: tests/scheme_conformance.rs)
# ---------------------------------------------------------------------------
#
# A tiny fc-only net (70 -> 9 -> 4, batch 2) whose every value is an
# integer or a power-of-two scale of one, so both languages compute the
# exact same f32 bit patterns regardless of summation order.  The input
# and parameter formulas below are integer arithmetic mirrored verbatim
# by the rust loader test — the .bkw file carries the parameters, the
# .logits sidecar carries the expected output bits in hex.

FIXTURE_DIR = (pathlib.Path(__file__).resolve().parents[2]
               / "rust" / "tests" / "fixtures")
FX_K, FX_D1, FX_CLASSES, FX_BATCH = 70, 9, 4, 2


def _fx_input():
    """Deterministic small-int batch: x[b,i] = ((7i + 3(b+1)) % 11) - 5."""
    x = np.empty((FX_BATCH, FX_K), np.float32)
    for b in range(FX_BATCH):
        for i in range(FX_K):
            x[b, i] = ((7 * i + 3 * (b + 1)) % 11) - 5
    return x


def _fx_sign_weight(d, k):
    """{-1,+1} weight matrix from an integer hash of the index."""
    w = np.empty((d, k), np.float32)
    for di in range(d):
        for ki in range(k):
            w[di, ki] = 1.0 if ((31 * di + 17 * ki) % 5) % 2 == 0 else -1.0
    return w


def _fx_ternary_weight(d, k):
    """{-1,0,+1} weight matrix from an integer hash of the index."""
    w = np.empty((d, k), np.float32)
    for di in range(d):
        for ki in range(k):
            w[di, ki] = ((31 * di + 17 * ki) % 3) - 1
    return w


def _fx_bn(d):
    """Power-of-two scales, small-int shifts: exact in f32."""
    a = np.asarray([2.0 ** ((di % 3) - 1) for di in range(d)], np.float32)
    b = np.asarray([float((di % 7) - 3) for di in range(d)], np.float32)
    return a, b


def _fx_alpha(d):
    """Power-of-two per-channel scales (0.5 or 2.0): exact in f32."""
    return np.asarray([2.0 ** (2 * (di % 2) - 1) for di in range(d)],
                      np.float32)


def _fx_layers(scheme):
    """[(w, alpha_or_None, bn_a, bn_b)] for the two fc layers."""
    make_w = (_fx_ternary_weight if scheme == "ternary_weight"
              else _fx_sign_weight)
    layers = []
    for d, k in ((FX_D1, FX_K), (FX_CLASSES, FX_D1)):
        alpha = _fx_alpha(d) if scheme == "xnor_alpha" else None
        a, b = _fx_bn(d)
        layers.append((make_w(d, k), alpha, a, b))
    return layers


def _fx_bytes(scheme):
    """The complete BKW2 fixture file for one scheme (no labels)."""
    code = train.SCHEMES[scheme]
    signs = scheme != "binary_weight"
    ops = [(train.OP_FLATTEN,)]
    for dout in (FX_D1, FX_CLASSES):
        if signs:
            ops.append((train.OP_SIGN,))
        ops.append((train.OP_LINEAR, dout, 1))
        ops.append((train.OP_BATCHNORM,))
    f = io.BytesIO()
    f.write(b"BKW2")
    f.write(struct.pack("<5I", 1, 1, FX_K, FX_CLASSES,
                        len(ops) + (1 if code else 0)))
    if code:
        f.write(struct.pack("<BI", train.OP_SCHEME, code))
    for op in ops:
        f.write(struct.pack("<B", op[0]))
        if op[0] == train.OP_LINEAR:
            f.write(struct.pack("<IB", *op[1:]))
    layers = _fx_layers(scheme)
    n_tensors = sum(3 + (lay[1] is not None) for lay in layers)
    f.write(struct.pack("<I", n_tensors))
    for fi, (w, alpha, a, b) in enumerate(layers, start=1):
        train._write_tensor(f, f"fc{fi}.w", w)
        if alpha is not None:
            train._write_tensor(f, f"fc{fi}.alpha", alpha)
        train._write_tensor(f, f"bn_fc{fi}.a", a)
        train._write_tensor(f, f"bn_fc{fi}.b", b)
    return f.getvalue()


def _fx_logits(scheme):
    """Numpy forward pass; every intermediate is exact in f32."""
    signs = scheme != "binary_weight"
    h = _fx_input()
    for w, alpha, a, b in _fx_layers(scheme):
        s = np.where(h >= 0, 1.0, -1.0).astype(np.float32) if signs else h
        g = (s @ w.T).astype(np.float32)
        if alpha is not None:
            g = alpha * g
        h = a * g + b
    return h.astype(np.float32)


def _fx_logits_hex(scheme):
    """One line per batch row: space-separated u32 hex of the f32 bits."""
    bits = _fx_logits(scheme).view(np.uint32)
    return "".join(" ".join(f"{v:08x}" for v in row) + "\n" for row in bits)


@pytest.mark.parametrize("scheme", sorted(train.SCHEMES))
def test_scheme_fixture_goldens_are_current(scheme):
    """Checked-in rust/tests/fixtures/* match what this file generates.

    On mismatch, regenerate with
        python python/tests/test_cross_language.py
    and re-run the rust side (cargo test --test scheme_conformance).
    """
    bkw = FIXTURE_DIR / f"scheme_{scheme}.bkw"
    logits = FIXTURE_DIR / f"scheme_{scheme}.logits"
    assert bkw.is_file() and logits.is_file(), \
        f"missing fixture for {scheme}; regenerate (see docstring)"
    assert bkw.read_bytes() == _fx_bytes(scheme), scheme
    assert logits.read_text() == _fx_logits_hex(scheme), scheme


def test_scheme_fixture_logits_are_integer_scaled():
    """Sanity: 4*logits is an exact integer for every scheme (so the
    bit-identity claim does not rest on rounding luck)."""
    for scheme in train.SCHEMES:
        q = _fx_logits(scheme) * 4.0
        assert (q == np.round(q)).all(), scheme


def test_scheme_fixture_declares_its_scheme(tmp_path):
    """load_bkw_scheme round-trips the scheme byte of every fixture."""
    for scheme in train.SCHEMES:
        p = tmp_path / f"{scheme}.bkw"
        p.write_bytes(_fx_bytes(scheme))
        assert train.load_bkw_scheme(str(p)) == scheme


if __name__ == "__main__":
    FIXTURE_DIR.mkdir(parents=True, exist_ok=True)
    for _scheme in sorted(train.SCHEMES):
        (FIXTURE_DIR / f"scheme_{_scheme}.bkw").write_bytes(
            _fx_bytes(_scheme))
        (FIXTURE_DIR / f"scheme_{_scheme}.logits").write_text(
            _fx_logits_hex(_scheme))
        print(f"wrote scheme_{_scheme}.bkw / .logits")
