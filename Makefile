# Repo-level convenience targets.
#
#   make ci        — tier-1 gate: build + tests + docs + fmt + clippy
#                    + smoke runs
#   make bench     — kernel ablation -> BENCH_2.json (per-impl GiOP/s
#                    for the Table-2 layer shapes, plus the
#                    quantization-scheme ablation table), the replica
#                    batching sweep (--quick) -> BENCH_3.json, the
#                    reload-under-load run (--quick, request loss must
#                    be 0) -> BENCH_6.json, and the panic-injection run
#                    (--quick, request loss must be 0) -> BENCH_7.json;
#                    drop --quick on any of them for full-fidelity
#                    numbers
#   make docs      — API docs only, rustdoc warnings denied
#   make artifacts — python AOT pipeline -> rust/artifacts (needs jax)

.PHONY: ci bench docs artifacts

ci:
	./scripts/ci.sh

bench:
	cd rust && cargo bench --bench ablation -- --json ../BENCH_2.json
	cd rust && cargo bench --bench batching -- --quick --json ../BENCH_3.json
	cd rust && cargo bench --bench lifecycle -- --quick --json ../BENCH_6.json
	cd rust && cargo bench --bench chaos -- --quick --json ../BENCH_7.json

docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
