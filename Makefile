# Repo-level convenience targets.
#
#   make ci        — tier-1 gate: build + tests + fmt + profile smoke run
#   make artifacts — python AOT pipeline -> rust/artifacts (needs jax)

.PHONY: ci artifacts

ci:
	./scripts/ci.sh

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
