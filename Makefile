# Repo-level convenience targets.
#
#   make ci        — tier-1 gate: build + tests + docs + fmt + clippy
#                    + smoke runs
#   make bench     — kernel ablation -> BENCH_2.json (per-impl GiOP/s
#                    for the Table-2 layer shapes, plus the
#                    quantization-scheme ablation table), the replica
#                    batching sweep (--quick) -> BENCH_3.json, the
#                    reload-under-load run (--quick, request loss must
#                    be 0) -> BENCH_6.json, the panic-injection run
#                    (--quick, request loss must be 0) -> BENCH_7.json,
#                    and the front-end load sweep (blocking vs
#                    --event-loop, p50/p99/p999 + req/s) ->
#                    BENCH_9.json; drop --quick on any of them for
#                    full-fidelity numbers (the full serve_load grid
#                    climbs to 10k connections — raise `ulimit -n`
#                    past ~25k first)
#   make docs      — API docs only, rustdoc warnings denied
#   make artifacts — python AOT pipeline -> rust/artifacts (needs jax)

.PHONY: ci bench docs artifacts

ci:
	./scripts/ci.sh

bench:
	cd rust && cargo bench --bench ablation -- --json ../BENCH_2.json
	cd rust && cargo bench --bench batching -- --quick --json ../BENCH_3.json
	cd rust && cargo bench --bench lifecycle -- --quick --json ../BENCH_6.json
	cd rust && cargo bench --bench chaos -- --quick --json ../BENCH_7.json
	cd rust && cargo bench --bench serve_load -- --quick --json ../BENCH_9.json

docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
