# Repo-level convenience targets.
#
#   make ci        — tier-1 gate: build + tests + fmt + clippy + smoke runs
#   make bench     — kernel ablation -> BENCH_2.json (per-impl GiOP/s
#                    for the Table-2 layer shapes; the perf trajectory)
#   make artifacts — python AOT pipeline -> rust/artifacts (needs jax)

.PHONY: ci bench artifacts

ci:
	./scripts/ci.sh

bench:
	cd rust && cargo bench --bench ablation -- --json ../BENCH_2.json

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
