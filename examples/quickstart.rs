//! Quickstart: the whole stack in one binary.
//!
//! 1. prints the paper's Table 1 (xnor == ±1 multiply),
//! 2. loads the trained BNN + test set from artifacts/,
//! 3. classifies a few images with every kernel arm (native rust AND the
//!    AOT-compiled PJRT executables) and shows the logits agree,
//! 4. prints per-arm timing for a single image.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::runtime::Runtime;
use bitkernel::utils::Stopwatch;

fn main() -> Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(),
                    "run `make artifacts` first");

    // --- Table 1: the xnor <-> multiply equivalence ------------------------
    let mut t1 = Table::new(
        "Table 1 — xnor(encodings) == multiply(values)",
        &["enc a (val)", "enc b (val)", "xnor (product)"],
    );
    for (ea, eb) in [(0u32, 0u32), (0, 1), (1, 0), (1, 1)] {
        let (va, vb) = (2 * ea as i32 - 1, 2 * eb as i32 - 1);
        let xnor = 1 ^ (ea ^ eb);
        t1.row(&[
            format!("{ea} ({va:+})"),
            format!("{eb} ({vb:+})"),
            format!("{xnor} ({:+})", va * vb),
        ]);
    }
    t1.print();

    // --- load model + data -------------------------------------------------
    let engine = BnnEngine::load(dir.join("weights_small.bkw"))?;
    let ds = Dataset::load(dir.join("dataset_test.bin"))?;
    println!(
        "loaded trained BNN ({} params) + {} test images",
        engine.spec.param_count(),
        ds.count
    );

    // --- classify with every native arm ------------------------------------
    let n = 6;
    let x = ds.normalized(0, n);
    let arms = [
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ];
    let mut table = Table::new(
        "Predictions per kernel arm (must agree)",
        &["image", "truth", "xnor", "control", "optimized"],
    );
    let preds: Vec<Vec<usize>> =
        arms.iter().map(|&k| engine.predict(&x, k)).collect();
    // Class names from the weight file's label table (numeric for
    // label-less files).
    let label = |c: usize| engine.label_for(c);
    for i in 0..n {
        table.row(&[
            format!("{i}"),
            label(ds.labels[i] as usize),
            label(preds[0][i]),
            label(preds[1][i]),
            label(preds[2][i]),
        ]);
    }
    table.print();
    assert_eq!(preds[0], preds[1]);
    assert_eq!(preds[0], preds[2]);
    println!("all native arms agree ✓");

    // --- PJRT (AOT pallas/XLA) arms (needs --features pjrt) ----------------
    let x1 = ds.normalized(0, 1);
    let native = engine.forward(&x1, EngineKernel::Xnor(XnorImpl::Blocked));
    match Runtime::new(&dir) {
        // Only the built-without-pjrt stub error is skippable; in a
        // pjrt build a Runtime failure is a real regression.
        Err(e) if !cfg!(feature = "pjrt") => {
            println!("\nskipping PJRT arms: {e:#}");
        }
        Err(e) => return Err(e),
        Ok(mut rt) => {
            println!("\nPJRT executables (jax/pallas AOT -> HLO text -> {}):",
                     rt.platform());
            for variant in ["xnor", "control", "optimized"] {
                let sw = Stopwatch::start();
                let model = rt.load_by("small", variant, 1)?;
                let compile_ms = sw.elapsed_ms();
                let sw = Stopwatch::start();
                let out = model.infer(&x1)?;
                let diff = out.max_abs_diff(&native);
                println!(
                    "  {variant:<10} compile {compile_ms:>7.1} ms   infer {:>7.2} ms   max|Δlogit| vs native = {diff:.2e}",
                    sw.elapsed_ms()
                );
                assert!(diff < 5e-3);
            }
            println!("PJRT arms agree with the native engine ✓");
        }
    }

    // --- single-image timing ------------------------------------------------
    // Compile the plan once per arm and time steady-state Session::run —
    // the serving configuration (plan compilation stays outside the loop).
    println!("\nsingle-image native timing (small model):");
    for &kernel in &arms {
        let mut session = engine.plan(kernel, 1)?.session();
        std::hint::black_box(session.run(&x1)); // warmup
        let sw = Stopwatch::start();
        let iters = 10;
        for _ in 0..iters {
            std::hint::black_box(session.run(&x1));
        }
        println!(
            "  {:<16} {:>8.2} ms/image",
            kernel.name(),
            sw.elapsed_ms() / iters as f64
        );
    }
    println!("\nquickstart done — see examples/table2.rs for the paper's \
              headline experiment");
    Ok(())
}
