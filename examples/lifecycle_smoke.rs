//! Lifecycle smoke gate: boot an EMPTY admin-enabled server on port 0,
//! then drive the whole model lifecycle over real TCP with the same
//! tiny client the `bitkernel mount/reload/unmount` subcommands use —
//! mount a synthetic BKW file, classify (bit-identical to
//! `forward_reference`), rewrite the weights and reload (generation
//! bump, new bits), unmount, and assert the name 404s everywhere.
//! The ci.sh proof that the admin API edits a live server end to end.
//!
//! Artifact-free: the weight file is written to a temp dir first.
//!
//! Run: `cargo run --release --example lifecycle_smoke`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{BatcherConfig, RouterConfig};
use bitkernel::data::normalize_batch;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec};
use bitkernel::server::{
    http_call, serve, ModelRegistry, RegistryConfig, ServeOptions,
    Service,
};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// The reference logits generation `seed` must serve for `px`.
fn oracle(spec: &NetSpec, seed: u64, px: &[u8]) -> Result<Vec<f32>> {
    let (c, h, w) = spec.input();
    let engine =
        BnnEngine::from_weight_file(&synthetic_weight_file(spec, seed))?;
    Ok(engine
        .forward_reference(&normalize_batch(px, 1, h, w, c), KERNEL)
        .data()
        .to_vec())
}

fn parse(body: &[u8]) -> Result<Json> {
    Json::parse(std::str::from_utf8(body).context("reply utf-8")?)
        .context("reply json")
}

fn generation_of(body: &[u8]) -> Result<u64> {
    Ok(parse(body)?
        .get("generation")
        .and_then(Json::as_f64)
        .context("missing generation")? as u64)
}

/// Classify and check the reply is bit-identical to `want`.
fn classify_check(
    addr: &str,
    px: &[u8],
    want: &[f32],
    ctx: &str,
) -> Result<u64> {
    let (status, body) =
        http_call(addr, "POST", "/classify?model=demo", px)?;
    ensure!(status == 200, "{ctx}: classify -> HTTP {status}");
    let v = parse(&body)?;
    let logits: Vec<f32> = v
        .get("logits")
        .and_then(|l| l.as_arr())
        .context("missing logits")?
        .iter()
        .map(|j| j.as_f64().unwrap_or(f64::NAN) as f32)
        .collect();
    ensure!(logits.len() == want.len(), "{ctx}: logit count");
    for (i, (g, w)) in logits.iter().zip(want).enumerate() {
        ensure!(
            g.to_bits() == w.to_bits(),
            "{ctx}: logit {i} not bit-identical ({g} vs {w})"
        );
    }
    generation_of(&body)
}

fn main() -> Result<()> {
    // --- one synthetic model on disk ---------------------------------------
    let dir = std::env::temp_dir().join("bitkernel_lifecycle_smoke");
    std::fs::create_dir_all(&dir)?;
    let spec = NetSpec::builder((1, 8, 8)).conv(4, 3).linear(5).build()?;
    let path = dir.join("demo.bkw");
    synthetic_weight_file(&spec, 1).save(&path)?;
    let px: Vec<u8> =
        (0..8 * 8).map(|i| ((i * 31 + 7) % 256) as u8).collect();

    // --- boot an EMPTY admin server on port 0 ------------------------------
    let registry = ModelRegistry::new(RegistryConfig {
        kernel: KERNEL,
        max_batch: 4,
        router: RouterConfig {
            queue_cap: 32,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(2),
            },
        },
        max_resident: 0,
    });
    let service = Arc::new(Service::with_registry(registry, None, true));
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let server = std::thread::spawn(move || {
        serve(
            service,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
    });
    let addr = ready_rx
        .recv_timeout(Duration::from_secs(10))
        .context("server did not come up")?
        .to_string();
    println!("server up on {addr} with zero models");
    let (status, body) = http_call(&addr, "GET", "/models", b"")?;
    ensure!(status == 200
                && parse(&body)?.as_arr().map(<[Json]>::len) == Some(0),
            "expected an empty model list");

    // --- mount over HTTP ---------------------------------------------------
    let body = Json::obj(vec![
        ("name", Json::Str("demo".into())),
        ("path", Json::Str(path.display().to_string())),
    ])
    .to_string();
    let (status, reply) =
        http_call(&addr, "POST", "/models?wait=1", body.as_bytes())?;
    ensure!(status == 201, "mount -> HTTP {status}: {}",
            String::from_utf8_lossy(&reply));
    let g1 = generation_of(&reply)?;
    println!("mounted demo (generation {g1})");

    let gen = classify_check(&addr, &px, &oracle(&spec, 1, &px)?,
                             "generation 1")?;
    ensure!(gen == g1, "reply generation {gen}, mounted {g1}");
    println!("classify: bit-identical to generation {g1}");

    // --- reload from rewritten weights -------------------------------------
    synthetic_weight_file(&spec, 2).save(&path)?;
    let (status, reply) =
        http_call(&addr, "PUT", "/models/demo?wait=1", b"")?;
    ensure!(status == 200, "reload -> HTTP {status}: {}",
            String::from_utf8_lossy(&reply));
    let g2 = generation_of(&reply)?;
    ensure!(g2 > g1, "reload must bump the generation ({g2} vs {g1})");
    let gen = classify_check(&addr, &px, &oracle(&spec, 2, &px)?,
                             "generation 2")?;
    ensure!(gen == g2, "reply generation {gen}, reloaded {g2}");
    println!("reloaded demo (generation {g2}), replies track the swap");

    // --- unmount -> clean 404s ---------------------------------------------
    let (status, _) = http_call(&addr, "DELETE", "/models/demo", b"")?;
    ensure!(status == 200, "unmount -> HTTP {status}");
    let (status, _) = http_call(&addr, "GET", "/models/demo", b"")?;
    ensure!(status == 404, "status after unmount -> HTTP {status}");
    let (status, _) =
        http_call(&addr, "POST", "/classify?model=demo", &px)?;
    ensure!(status == 404, "classify after unmount -> HTTP {status}");
    let (status, body) = http_call(&addr, "GET", "/models", b"")?;
    ensure!(status == 200
                && parse(&body)?.as_arr().map(<[Json]>::len) == Some(0),
            "model list must be empty again");
    println!("unmounted demo; every route 404s the name");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap()?;
    let _ = std::fs::remove_dir_all(&dir);
    println!("lifecycle smoke passed");
    Ok(())
}
