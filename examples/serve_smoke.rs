//! Serve smoke gate: boot the HTTP service on port 0 over TWO
//! synthetic weight files with different input shapes and class
//! counts (one carrying a label table, one label-less), classify
//! against each over real TCP, and assert 200s, per-model logits
//! widths, and the label fallback — the ci.sh proof that a single
//! `serve` process answers heterogeneous binarized nets end to end.
//!
//! Artifact-free: the weight files are written to a temp dir first,
//! so this also exercises the BKW2 + trailing-labels disk round trip
//! through `BnnEngine::load`.
//!
//! Run: `cargo run --release --example serve_smoke`

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, NativeBackend, Router, RouterConfig,
};
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec};
use bitkernel::server::{serve, ServeOptions, Service};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;

fn start_router(engine: &BnnEngine) -> Result<Router> {
    let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4)?;
    Router::start(
        move |_replica| {
            Ok(Box::new(NativeBackend::from_plan(&plan))
                as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 32,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(2),
            },
        },
    )
}

fn main() -> Result<()> {
    // --- two synthetic models on disk --------------------------------------
    let dir = std::env::temp_dir().join("bitkernel_serve_smoke");
    std::fs::create_dir_all(&dir)?;

    // "shapes": paper-shaped 3x32x32/10 conv net WITH a label table.
    let spec_a = NetSpec::builder((3, 32, 32))
        .conv(8, 3)
        .pool()
        .linear(10)
        .build()?;
    let mut wf_a = synthetic_weight_file(&spec_a, 5);
    let labels: Vec<String> =
        (0..10).map(|i| format!("shape-{i}")).collect();
    wf_a.set_labels(Some(labels.clone()));
    let path_a = dir.join("shapes.bkw");
    wf_a.save(&path_a)?;

    // "letters": fc-heavy 1x28x28/26 net, label-less (numeric labels).
    let spec_b = NetSpec::builder((1, 28, 28))
        .linear(48)
        .linear(26)
        .build()?;
    let path_b = dir.join("letters.bkw");
    synthetic_weight_file(&spec_b, 6).save(&path_b)?;

    // --- one service over both (the multi-`--model` serve path) ------------
    let engine_a = BnnEngine::load(&path_a)?;
    ensure!(engine_a.labels() == Some(&labels[..]),
            "labels lost in the disk round trip");
    let engine_b = BnnEngine::load(&path_b)?;
    let mut routers = BTreeMap::new();
    routers.insert("shapes".to_string(), start_router(&engine_a)?);
    routers.insert("letters".to_string(), start_router(&engine_b)?);
    let service = Arc::new(Service::new(routers, "shapes"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let svc = Arc::clone(&service);
    let stop2 = Arc::clone(&stop);
    let server = std::thread::spawn(move || {
        serve(
            svc,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
    });
    let addr = ready_rx
        .recv_timeout(Duration::from_secs(10))
        .context("server never came up")?;
    println!("serve_smoke: listening on {addr}");

    // --- /models advertises both contracts ----------------------------------
    let (status, body) = http_get(&addr, "/models")?;
    ensure!(status == 200, "/models -> {status}");
    ensure!(body.contains("\"shapes\"") && body.contains("\"letters\""),
            "/models missing a model: {body}");
    println!("serve_smoke: /models ok ({body})");

    // --- classify each model with its own byte count ------------------------
    for (model, elems, classes, labelled) in
        [("shapes", 3 * 32 * 32, 10, true), ("letters", 28 * 28, 26, false)]
    {
        let px: Vec<u8> = (0..elems).map(|i| (i % 251) as u8).collect();
        let (status, body) =
            http_post(&addr, &format!("/classify?model={model}"), &px)?;
        ensure!(status == 200, "{model}: {status} {body}");
        let v = Json::parse(&body)
            .map_err(|e| anyhow::anyhow!("{model} reply: {e}"))?;
        let class = v
            .get("class")
            .and_then(Json::as_usize)
            .context("reply missing class")?;
        ensure!(class < classes, "{model}: class {class}");
        let label = v
            .get("label")
            .and_then(Json::as_str)
            .context("reply missing label")?;
        let expected = if labelled {
            format!("shape-{class}")
        } else {
            class.to_string() // numeric fallback for label-less models
        };
        ensure!(label == expected,
                "{model}: label '{label}', expected '{expected}'");
        let n_logits = v
            .get("logits")
            .and_then(Json::as_arr)
            .map(<[Json]>::len);
        ensure!(n_logits == Some(classes),
                "{model}: logits {n_logits:?}");
        println!(
            "serve_smoke: {model} ({elems} bytes) -> 200, class {class} \
             '{label}', {classes} logits ok"
        );
    }

    // --- wrong-size body is a clean 400 -------------------------------------
    let (status, body) =
        http_post(&addr, "/classify?model=letters", &[0u8; 100])?;
    ensure!(status == 400, "undersized body -> {status} {body}");
    println!("serve_smoke: wrong-size body -> 400 ok");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap()?;
    println!("serve_smoke: all green");
    Ok(())
}

// --- tiny blocking HTTP client ---------------------------------------------

fn http_get(addr: &std::net::SocketAddr, path: &str)
            -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream,
           "GET {path} HTTP/1.1\r\nHost: s\r\nConnection: close\r\n\r\n")?;
    read_response(stream)
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8])
             -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: s\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    read_response(stream)
}

fn read_response(stream: TcpStream) -> Result<(u16, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:")
        {
            len = v.trim().parse()?;
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8(body)?))
}
