//! Regenerate the paper's Table 2 (the headline experiment).
//!
//! Run: `make artifacts && cargo run --release --example table2`
//! Flags: `-- --quick` for a fast low-sample pass,
//!        `-- --weights small` to use the trained small model instead of
//!        the full-scale network.

use anyhow::Result;

use bitkernel::benchkit::table2::{run, Table2Options};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let weights = args
        .iter()
        .position(|a| a == "--weights")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "full".to_string());

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(),
                    "run `make artifacts` first");

    let opts = if quick {
        Table2Options {
            native_images: 4,
            native_control_images: 1,
            pjrt_batches: 1,
            weights,
        }
    } else {
        Table2Options { weights, ..Default::default() }
    };

    println!("testbed: {} (single-node CPU; see DESIGN.md §5 for the \
              column substitutions)", std::env::consts::ARCH);
    let result = run(&dir, &opts, |line| println!("{line}"))?;
    println!("{}", result.render());

    // The reproduction claim: orderings, not absolute seconds.
    assert!(
        result.native_speedup() > 1.5,
        "native xnor should beat the control group comfortably"
    );
    if result.has_pjrt() {
        assert!(
            result.pjrt_speedup() > 1.0,
            "pjrt xnor should beat the pallas control group"
        );
    } else {
        println!("(pjrt column skipped: built without the pjrt feature)");
    }
    println!("orderings consistent with the paper ✓");
    Ok(())
}
