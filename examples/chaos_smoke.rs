//! Chaos smoke gate: boot the HTTP service on port 0 with a live
//! [`FaultPlan`] installed, then prove the failure contract over real
//! TCP — the ci.sh drill for supervision, deadlines, and recovery:
//!
//! 1. with an injected per-batch delay, `/classify` still answers 200
//!    bit-identical to `forward_reference`;
//! 2. `?timeout_ms=1` under that delay is a clean `504` (the deadline
//!    is end-to-end, not a client-side timer);
//! 3. an armed replica panic surfaces as a typed `500` ("replica
//!    panicked"), never a hang or a dropped connection;
//! 4. the pool respawns (`bitkernel_replica_restarts` climbs on
//!    `/metrics`) and post-recovery replies are again 200 and
//!    bit-identical.
//!
//! Artifact-free: runs against a synthetic engine.
//!
//! Run: `cargo run --release --example chaos_smoke`

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, NativeBackend, Router, RouterConfig,
};
use bitkernel::data::normalize_batch;
use bitkernel::model::EngineKernel;
use bitkernel::server::{http_call, serve, ServeOptions, Service};
use bitkernel::testing::chaos::FaultPlan;
use bitkernel::testing::synthetic_engine;
use bitkernel::utils::json::Json;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// Classify `px` and, on 200, check the logits against `want`
/// bit-for-bit.  Returns the HTTP status and body either way.
fn classify(addr: &str, path: &str, px: &[u8], want: &[f32])
            -> Result<(u16, String)> {
    let (status, body) = http_call(addr, "POST", path, px)?;
    let body = String::from_utf8_lossy(&body).into_owned();
    if status == 200 {
        let v = Json::parse(&body).context("reply json")?;
        let logits: Vec<f32> = v
            .get("logits")
            .and_then(|l| l.as_arr())
            .context("missing logits")?
            .iter()
            .map(|j| j.as_f64().unwrap_or(f64::NAN) as f32)
            .collect();
        ensure!(logits.len() == want.len(), "logit count");
        for (i, (g, w)) in logits.iter().zip(want).enumerate() {
            ensure!(
                g.to_bits() == w.to_bits(),
                "logit {i} not bit-identical ({g} vs {w}) — chaos must \
                 never corrupt a surviving reply"
            );
        }
    }
    Ok((status, body))
}

/// Sum of every `bitkernel_replica_restarts` sample on `/metrics`.
fn total_restarts(addr: &str) -> Result<u64> {
    let (status, body) = http_call(addr, "GET", "/metrics", b"")?;
    ensure!(status == 200, "/metrics -> {status}");
    Ok(String::from_utf8_lossy(&body)
        .lines()
        .filter(|l| l.starts_with("bitkernel_replica_restarts"))
        .filter_map(|l| l.rsplit(' ').next()?.parse::<u64>().ok())
        .sum())
}

fn main() -> Result<()> {
    // A live fault plan for the whole process: every batch is delayed
    // a little (so deadlines have something to race) and panics are
    // armed on demand below.  `serve` deployments get the same effect
    // from BITKERNEL_CHAOS.
    let guard =
        FaultPlan::new().delay(Duration::from_millis(10)).install();

    let engine = synthetic_engine([8, 8, 8, 8, 8, 8, 16, 16, 10], 3);
    let plan = engine.plan(KERNEL, 4)?;
    let router = Router::start(
        move |_replica| {
            Ok(Box::new(NativeBackend::from_plan(&plan))
                as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 64,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
        },
    )?;
    let mut routers = BTreeMap::new();
    routers.insert("demo".to_string(), router);
    let service = Arc::new(Service::new(routers, "demo"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let server = std::thread::spawn(move || {
        serve(
            service,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
    });
    let addr = ready_rx
        .recv_timeout(Duration::from_secs(10))
        .context("server never came up")?
        .to_string();
    println!("chaos_smoke: listening on {addr} (10ms injected delay)");

    let px: Vec<u8> =
        (0..3 * 32 * 32).map(|i| ((i * 31 + 7) % 256) as u8).collect();
    let want = engine
        .forward_reference(&normalize_batch(&px, 1, 32, 32, 3), KERNEL)
        .data()
        .to_vec();

    // 1. Delayed but healthy: 200 and bit-identical.
    let (status, body) =
        classify(&addr, "/classify?model=demo", &px, &want)?;
    ensure!(status == 200, "baseline classify -> {status} {body}");
    println!("chaos_smoke: delayed classify -> 200, bit-identical");

    // 2. A 1ms end-to-end deadline cannot survive a 10ms injected
    //    delay: typed 504, not a hang.
    let (status, body) =
        classify(&addr, "/classify?model=demo&timeout_ms=1", &px, &want)?;
    ensure!(status == 504, "deadline classify -> {status} {body}");
    ensure!(body.contains("deadline"), "504 body: {body}");
    println!("chaos_smoke: timeout_ms=1 -> 504 '{body}'");

    // 3. Arm a panic on both replicas: the next classifies surface a
    //    typed 500 (and never hang), while supervision respawns.
    guard.plan().arm_panic(0);
    guard.plan().arm_panic(1);
    let mut panics_seen = 0usize;
    let deadline = Instant::now() + Duration::from_secs(20);
    while panics_seen < 2 {
        ensure!(
            Instant::now() < deadline,
            "armed panics never surfaced ({panics_seen} seen)"
        );
        let (status, body) =
            classify(&addr, "/classify?model=demo", &px, &want)?;
        match status {
            200 => {}
            500 => {
                ensure!(body.contains("panicked"), "500 body: {body}");
                panics_seen += 1;
                println!("chaos_smoke: injected panic -> 500 '{body}'");
            }
            // Both replicas briefly mid-respawn: the circuit answers
            // typed 503s until one rejoins.
            503 => std::thread::sleep(Duration::from_millis(10)),
            other => bail!("unexpected HTTP {other}: {body}"),
        }
    }

    // 4. Recovery: restart counters climb and replies go green again.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let restarts = total_restarts(&addr)?;
        if restarts >= 2 {
            println!(
                "chaos_smoke: /metrics shows {restarts} replica restarts"
            );
            break;
        }
        ensure!(
            Instant::now() < deadline,
            "replicas never respawned (restarts = {restarts})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, body) =
        classify(&addr, "/classify?model=demo", &px, &want)?;
    ensure!(status == 200, "post-recovery classify -> {status} {body}");
    println!("chaos_smoke: post-recovery classify -> 200, bit-identical");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap()?;
    drop(guard);
    println!("chaos_smoke: all green");
    Ok(())
}
