//! Accuracy parity — the paper's Sec. 1 claim that a BNN "could achieve
//! 89% accuracy on CIFAR-10" carries over to our substitution dataset,
//! and (the real point) binarized xnor inference loses NOTHING vs the
//! float simulation of the same binarized network.
//!
//! Run: `make artifacts && cargo run --release --example accuracy`

use anyhow::Result;

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::utils::Stopwatch;

fn main() -> Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--images")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);

    let ds = Dataset::load(dir.join("dataset_test.bin"))?;
    let engine = BnnEngine::load(dir.join("weights_small.bkw"))?;
    let n = n.min(ds.count);
    let x = ds.normalized(0, n);
    println!(
        "trained BNN (scale 0.25, {} params) on ShapeSet-10, {} test images",
        engine.spec.param_count(),
        n
    );

    let mut table = Table::new(
        "Accuracy parity across kernel arms",
        &["kernel", "accuracy", "eval time", "img/s"],
    );
    let mut accs = Vec::new();
    for kernel in [
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ] {
        let sw = Stopwatch::start();
        let acc = engine.evaluate(&x, &ds.labels[..n], kernel, 32);
        let secs = sw.elapsed_secs();
        table.row(&[
            kernel.name().into_owned(),
            format!("{:.2}%", acc * 100.0),
            format!("{secs:.2}s"),
            format!("{:.0}", n as f64 / secs),
        ]);
        accs.push(acc);
    }
    table.print();

    assert!(accs.iter().all(|&a| (a - accs[0]).abs() < 1e-6),
            "arms must agree exactly");
    assert!(accs[0] >= 0.89,
            "trained BNN should be at/above the paper's 89% reference; got {}",
            accs[0]);
    println!(
        "binarized xnor inference matches the float simulation exactly, at \
         {:.1}% accuracy (paper's CIFAR-10 reference point: 89%) ✓",
        accs[0] * 100.0
    );

    // Per-class breakdown (confusion row) for the xnor arm.
    let preds = engine.predict(&x, EngineKernel::Xnor(XnorImpl::Blocked));
    let mut per_class = [[0usize; 2]; 10]; // [correct, total]
    for i in 0..n {
        let t = ds.labels[i] as usize;
        per_class[t][1] += 1;
        if preds[i] == t {
            per_class[t][0] += 1;
        }
    }
    let mut t2 = Table::new("Per-class accuracy (xnor arm)",
                            &["class", "correct/total", "accuracy"]);
    for (c, [ok, total]) in per_class.iter().enumerate() {
        // Class name from the weight file's label table (numeric for
        // label-less files).
        t2.row(&[
            engine.label_for(c),
            format!("{ok}/{total}"),
            format!("{:.1}%", 100.0 * *ok as f64 / (*total).max(1) as f64),
        ]);
    }
    t2.print();
    Ok(())
}
