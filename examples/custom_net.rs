//! custom_net — serve a NON-CIFAR architecture end to end, artifact-free.
//!
//! The NetSpec IR makes the engine architecture-generic: this example
//! builds a 1x28x28, 26-class conv net (nothing like the paper's CIFAR
//! topology), gives it synthetic binarized weights, round-trips it
//! through a BKW2 file on disk, compiles an xnor/auto plan, and checks
//! the zero-alloc session path against the unfused oracle bit-for-bit.
//!
//!     cargo run --release --example custom_net
//!
//! No `make artifacts` needed — weights are synthesized in memory.

use anyhow::Result;

use bitkernel::bitops::XnorImpl;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec, WeightFile};
use bitkernel::tensor::Tensor;
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::Rng;

fn main() -> Result<()> {
    // 1. Describe the architecture.  The builder inserts the
    //    Sign/BatchNorm/Flatten plumbing and binarizes every weighted
    //    layer after the first; shape arithmetic is validated here,
    //    with typed SpecErrors instead of mid-inference panics.
    let spec = NetSpec::builder((1, 28, 28))
        .conv(16, 3)
        .pool()
        .conv(32, 3)
        .pool()
        .linear(64)
        .linear(26)
        .build()?;
    println!(
        "spec: input {:?}, {} classes, {} params, {} ops",
        spec.input(),
        spec.classes(),
        spec.param_count(),
        spec.layers().len()
    );
    for (op, shape) in spec.layers().iter().zip(spec.output_shapes()) {
        println!("  {:<10} -> {shape}", op.op_name());
    }

    // 2. Synthetic weights (random signs + folded BN), written as a
    //    BKW2 file: the spec travels INSIDE the weight file, so the
    //    serving side needs no out-of-band architecture knowledge.
    let wf = synthetic_weight_file(&spec, 7);
    let path = std::env::temp_dir().join("bitkernel_custom_net.bkw");
    wf.save(&path)?;
    let loaded = WeightFile::load(&path)?;
    println!(
        "\nround-trip: wrote BKW{} to {}, read back BKW{}",
        wf.version(),
        path.display(),
        loaded.version()
    );
    assert_eq!(loaded.embedded_spec(), Some(&spec));

    // 3. Engine + compiled plan on the paper's kernel (auto-dispatch).
    let engine = BnnEngine::from_weight_file(&loaded)?;
    let kernel = EngineKernel::Xnor(XnorImpl::Auto);
    let plan = engine.plan(kernel, 8)?;
    println!("\nplan ({} / max_batch 8):", kernel.name());
    for name in plan.stage_names() {
        println!("  {name}");
    }
    println!("session buffers:");
    for (name, elems, bytes) in plan.buffer_sizes() {
        println!("  {name:<20} {elems:>8} elems  {:>8.1} KiB",
                 bytes as f64 / 1024.0);
    }

    // 4. Serve a batch and pin it against the unfused oracle.
    let mut rng = Rng::new(42);
    let x = Tensor::new(vec![4, 1, 28, 28], rng.normal_vec(4 * 28 * 28));
    let mut session = plan.session();
    let logits = session.run(&x).clone();
    let oracle = engine.forward_reference(&x, kernel);
    assert_eq!(logits.shape(), &[4, 26]);
    assert_eq!(logits.max_abs_diff(&oracle), 0.0,
               "plan must match the oracle bit-exactly");
    println!(
        "\nran batch of 4: logits [4, 26], bit-identical to \
         forward_reference — a 28x28/26-class net on the same kernel \
         that serves the paper's CIFAR net."
    );
    let _ = std::fs::remove_file(&path);
    Ok(())
}
