//! End-to-end serving driver (the DESIGN.md §7 "E2E serving" row).
//!
//! Boots the full request path — HTTP server -> router -> dynamic
//! batcher -> trained BNN on the native xnor kernel — then fires a
//! multi-client closed-loop load generator at it over real TCP and
//! reports throughput, latency percentiles, batching behaviour and
//! prediction accuracy.  Proves every layer composes with python
//! nowhere on the path.
//!
//! Run: `make artifacts && cargo run --release --example serve_load`
//! Flags: `-- --requests N` (default 256), `-- --clients C` (default 8),
//!        `-- --backend pjrt-xnor|native-xnor` (default native-xnor),
//!        `-- --replicas R` (0 or absent: one per core, capped at 8;
//!        native replicas share ONE compiled plan)

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use bitkernel::benchkit::Table;
use bitkernel::coordinator::{
    Backend, BatcherConfig, NativeBackend, PjrtBackend, Router, RouterConfig,
};
use bitkernel::data::Dataset;
use bitkernel::model::BnnEngine;
use bitkernel::runtime::Runtime;
use bitkernel::server::{serve, ServeOptions, Service};
use bitkernel::utils::timer::{mean, percentile};
use bitkernel::utils::Stopwatch;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize =
        flag(&args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(256);
    let clients: usize =
        flag(&args, "--clients").and_then(|v| v.parse().ok()).unwrap_or(8);
    let backend_kind =
        flag(&args, "--backend").unwrap_or_else(|| "native-xnor".into());
    let replicas: usize = match flag(&args, "--replicas")
        .and_then(|v| v.parse().ok())
    {
        None | Some(0) => bitkernel::coordinator::default_replicas(),
        Some(n) => n,
    };

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let ds = Arc::new(Dataset::load(dir.join("dataset_test.bin"))?);

    // --- boot the service ----------------------------------------------------
    let weights = dir.join("weights_small.bkw");
    let artifacts = dir.clone();
    let bk = backend_kind.clone();
    // Native arm: compile ONE plan up front; each replica mints its own
    // session from it inside its worker thread.
    let shared_plan = if bk == "native-xnor" {
        let engine = BnnEngine::load(&weights)?;
        Some(engine.plan(
            bitkernel::model::EngineKernel::Xnor(
                bitkernel::bitops::XnorImpl::Auto,
            ),
            8,
        )?)
    } else {
        None
    };
    let router = Router::start(
        move |_replica| -> anyhow::Result<Box<dyn Backend>> {
            match bk.as_str() {
                "native-xnor" => Ok(Box::new(NativeBackend::from_plan(
                    shared_plan.as_ref().expect("plan compiled above"),
                ))),
                "pjrt-xnor" => {
                    let mut rt = Runtime::new(&artifacts)?;
                    let name = rt
                        .manifest
                        .find_model("small", "xnor", 8)?
                        .name
                        .clone();
                    rt.load_model(&name)?;
                    Ok(Box::new(PjrtBackend::new(rt.take_model(&name)?)))
                }
                other => anyhow::bail!("unknown backend '{other}'"),
            }
        },
        RouterConfig {
            queue_cap: 512,
            replicas,
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(4),
            },
        },
    )?;
    let backend_name = router.backend_name().to_string();
    let metrics = router.metrics();
    let mut routers = BTreeMap::new();
    routers.insert("bnn".to_string(), router);
    let service = Arc::new(Service::new(routers, "bnn"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let svc2 = Arc::clone(&service);
    let stop2 = Arc::clone(&stop);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 8,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(15))?;
    println!("serving BNN on http://{addr} (backend {backend_name}, \
              {replicas} replicas, max_batch 8, max_delay 4ms)");

    // --- closed-loop load generator ------------------------------------------
    println!("load: {clients} clients x {} requests each",
             requests / clients);
    let next = Arc::new(AtomicUsize::new(0));
    let correct = Arc::new(AtomicUsize::new(0));
    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    let mut all_latencies: Vec<Vec<f64>> = Vec::new();
    for _ in 0..clients {
        let ds = Arc::clone(&ds);
        let next = Arc::clone(&next);
        let correct = Arc::clone(&correct);
        let addr = addr;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut latencies = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= requests {
                    return latencies;
                }
                let idx = i % ds.count;
                let sw = Stopwatch::start();
                let (status, body) =
                    http_post(&addr, "/classify", ds.image(idx));
                latencies.push(sw.elapsed_ms());
                assert_eq!(status, 200, "{body}");
                let v = bitkernel::utils::json::Json::parse(&body).unwrap();
                let class = v.get("class").unwrap().as_usize().unwrap();
                if class == ds.labels[idx] as usize {
                    correct.fetch_add(1, Ordering::SeqCst);
                }
            }
        }));
    }
    for h in handles {
        all_latencies.push(h.join().unwrap());
    }
    let wall = sw.elapsed_secs();

    // --- report ---------------------------------------------------------------
    let lat: Vec<f64> = all_latencies.into_iter().flatten().collect();
    let snap = metrics.snapshot();
    let mut t = Table::new(
        "End-to-end serving (HTTP -> batcher -> BNN xnor kernel)",
        &["metric", "value"],
    );
    t.row(&["backend".into(), backend_name]);
    t.row(&["requests".into(), format!("{requests}")]);
    t.row(&["concurrent clients".into(), format!("{clients}")]);
    t.row(&["wall time".into(), format!("{wall:.2}s")]);
    t.row(&["throughput".into(),
            format!("{:.1} req/s", requests as f64 / wall)]);
    t.row(&["latency mean".into(), format!("{:.2} ms", mean(&lat))]);
    t.row(&["latency p50".into(),
            format!("{:.2} ms", percentile(&lat, 0.50))]);
    t.row(&["latency p95".into(),
            format!("{:.2} ms", percentile(&lat, 0.95))]);
    t.row(&["latency p99".into(),
            format!("{:.2} ms", percentile(&lat, 0.99))]);
    t.row(&["server batches".into(), format!("{}", snap.batches)]);
    t.row(&["mean batch size".into(),
            format!("{:.2}", snap.mean_batch_size)]);
    t.row(&["queue p99".into(),
            format!("{:.2} ms", snap.queue_p99_us as f64 / 1e3)]);
    for (i, r) in snap.replicas.iter().enumerate() {
        t.row(&[format!("replica {i} req / busy"),
                format!("{} / {:.0} ms", r.requests,
                        r.busy_us as f64 / 1e3)]);
    }
    t.row(&["accuracy".into(),
            format!("{:.1}%",
                    100.0 * correct.load(Ordering::SeqCst) as f64
                        / requests as f64)]);
    t.print();

    assert_eq!(snap.completed as usize, requests);
    assert!(correct.load(Ordering::SeqCst) as f64 / requests as f64 > 0.9,
            "served predictions should match labels");
    // With a wide replica pool and few closed-loop clients, singleton
    // batches are the CORRECT outcome (there is never a queue), so only
    // assert batching when clients genuinely outnumber the pool.
    if clients >= 2 * replicas {
        assert!(snap.mean_batch_size > 1.0,
                "dynamic batching should form multi-request batches");
    }
    println!("end-to-end path verified ✓");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap()?;
    Ok(())
}

// --- minimal HTTP client ----------------------------------------------------

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}
