#!/usr/bin/env bash
# Tier-1 verification in one command (also: `make ci`).
#
#   build (release) -> tests -> docs -> formatting -> clippy
#   -> bench smoke runs
#
# The netspec suite pins the NetSpec IR: BKW1->legacy-spec
# equivalence, BKW2 writer/reader round trips, and randomized
# topologies bit-identical to the unfused oracle; the custom_net
# example drives the same path end to end (builder -> BKW2 file ->
# xnor/auto plan -> serve), all artifact-free.
# The docs step denies rustdoc warnings, so missing public-item docs
# (lib.rs sets #![warn(missing_docs)]) and broken intra-doc links fail
# CI.  The profile smoke run exercises the compiled plan/session path
# end to end (1 rep per arm); it self-skips when `make artifacts` has
# not been run, so ci.sh works in artifact-less environments too.  The
# ablation smoke run (--quick) exercises every xnor kernel impl — incl.
# the SIMD tiers, tiled threading, and Auto dispatch — on real layer
# shapes; the batching smoke run (--quick) drives the replica pool end
# to end on a synthetic model.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== shape-generic guard: no hardwired image-geometry constants"
# The serving path derives every geometry from the model's shape
# contract; reintroducing a global image constant regresses that.
if grep -rnE "IMAGE_ELEMS|IMAGE_BYTES" src; then
    echo "hardwired image-geometry constant reintroduced in rust/src" >&2
    exit 1
fi

echo "== scheme containment: QuantScheme variants only in the lowering files"
# Every match on a QuantScheme variant lives in model/spec.rs,
# model/plan.rs, model/bnn.rs, or nn/fuse.rs; the rest of the tree
# goes through the helper predicates (name/wire_byte/signs_activations/
# has_alpha/is_ternary/is_default) so a new scheme cannot silently
# half-propagate through format/serving/CLI code.
if grep -rnE "QuantScheme::(SignSign|XnorAlpha|BinaryWeight|TernaryWeight)" src \
    | grep -vE "^src/(model/(spec|plan|bnn)|nn/fuse)\.rs:"; then
    echo "QuantScheme variant used outside spec/plan/bnn/fuse" >&2
    exit 1
fi

echo "== dispatch gate: every XnorImpl variant is routed"
# The compiler catches a missing match arm, but NOT a new arm that
# never makes it into ALL_SINGLE — such an arm would be silently
# unrouted: never calibrated (model/plan.rs Auto resolution and the
# persistent calib cache both sweep ALL_SINGLE), never differential-
# fuzzed by prop_bitops, never ablated.  Extract the variant list from
# the enum itself so a future arm is gated the day it is added.
variants=$(sed -n '/^pub enum XnorImpl/,/^}/p' src/bitops/xnor.rs \
    | grep -oE '^    [A-Z][A-Za-z0-9]*' | tr -d ' ')
if [ -z "$variants" ]; then
    echo "could not extract XnorImpl variants from bitops/xnor.rs" >&2
    exit 1
fi
all_single=$(sed -n '/ALL_SINGLE:/,/\];/p' src/bitops/xnor.rs)
for v in $variants; do
    if ! grep -qE "XnorImpl::$v(\([a-z_]+\))? =>" src/bitops/xnor.rs; then
        echo "XnorImpl::$v has no dispatch arm in bitops/xnor.rs" >&2
        exit 1
    fi
    case "$v" in Auto|Threaded) continue ;; esac
    if ! echo "$all_single" | grep -q "XnorImpl::$v,"; then
        echo "XnorImpl::$v missing from ALL_SINGLE: the arm would never" \
             "be calibrated (plan.rs Auto path) or fuzzed" >&2
        exit 1
    fi
done

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== spec IR: BKW round-trip + randomized-topology property tests"
cargo test -q --test netspec

echo "== scheme conformance: scheme x kernel x topology matrix"
# Every quantization scheme (sign_sign, xnor_alpha, binary_weight,
# ternary_weight) on every kernel arm and a topology sweep, each cell
# bit-identical to the scheme-aware oracle; BKW2 scheme round trip,
# legacy default, pinned wire bytes, and the python-generated fixture
# goldens under tests/fixtures (twin: python/tests/test_cross_language.py).
cargo test -q --test scheme_conformance

echo "== shape-generic serving: heterogeneous models + submit validation"
# Includes the adversarial-client suite (slowloris, pipelining,
# mid-body disconnects) run against BOTH front ends.
cargo test -q --test serving

echo "== event-loop front end: epoll reactor acceptance"
# Bit-identical to forward_reference through the reactor, 504 deadline
# mapping, slow inference never blocking the loop, 503 connection
# shedding, and a concurrent keep-alive sweep with zero loss.
cargo test -q --test eventloop

echo "== model lifecycle: mount/reload/unmount under live traffic"
# Admin-API roundtrip, reload-under-hammer (every reply bit-identical
# to its generation's forward_reference, zero drops), unmount under
# traffic draining to clean 404s, lazy mounts, LRU demotion, metrics
# GC.  Artifact-free.
cargo test -q --test lifecycle

echo "== calibration cache: double-build + reload run zero microbenches"
# Separate test binary on purpose: it configures the process-global
# cache via BITKERNEL_CALIB_CACHE/BITKERNEL_CALIBRATE, builds the same
# Auto plan twice, and registry-mounts + reloads a model — asserting
# via bitkernel_calibrations_total that only cold shapes ever bench.
cargo test -q --test calib_cache

echo "== example: custom_net (NetSpec end to end, artifact-free)"
cargo run --release --example custom_net

echo "== serve smoke: two heterogeneous models behind one port"
# Boots the HTTP service on port 0 over two synthetic weight files
# with different input shapes and class counts, classifies against
# each over TCP (curl-equivalent), and asserts 200s + the label
# fallback for label-less files.  Artifact-free.
cargo run --release --example serve_smoke

echo "== lifecycle smoke: admin API edits a live server end to end"
# Boots an EMPTY admin server on port 0, mounts a synthetic model over
# HTTP, classifies (bit-identical), reloads (generation bump), and
# unmounts (clean 404s).  Artifact-free.
cargo run --release --example lifecycle_smoke

echo "== chaos: replica supervision, deadlines, fault injection"
# Hammers a 4-replica router under injected panics/delays: every
# client gets a reply or a typed error within its deadline (zero
# hangs), survivors stay bit-identical to forward_reference, and the
# pool converges back to full strength.  Artifact-free.
cargo test -q --test chaos

echo "== chaos smoke: injected faults over real TCP"
# Boots the HTTP service with a live fault plan: delayed classify
# stays bit-identical, timeout_ms races the delay to a typed 504,
# armed panics surface as typed 500s, and /metrics shows the respawns.
cargo run --release --example chaos_smoke

echo "== cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== bench smoke: kernel ablation (--quick)"
cargo bench --bench ablation -- --quick

echo "== bench smoke: per-impl kernel throughput (--quick)"
# Times every single-core arm (incl. the AVX-512 tier) on the
# acceptance shape; on VPOPCNTDQ hosts it asserts avx512 beats simd.
cargo bench --bench kernels -- --quick

echo "== bench smoke: profile (1 rep)"
cargo bench --bench profile -- --reps 1

echo "== bench smoke: replica batching (--quick)"
cargo bench --bench batching -- --quick

echo "== bench smoke: reload under load (--quick; asserts 0 lost)"
cargo bench --bench lifecycle -- --quick

echo "== bench smoke: panic injection under load (--quick; asserts 0 lost)"
cargo bench --bench chaos -- --quick

echo "== bench smoke: front-end load sweep (--quick; both front ends)"
# Drives blocking AND event-loop front ends with multiplexed
# keep-alive clients; asserts the event loop loses zero requests.
cargo bench --bench serve_load -- --quick

echo "ci.sh: all green"
