#!/usr/bin/env bash
# Tier-1 verification in one command (also: `make ci`).
#
#   build (release) -> tests -> formatting -> profile-bench smoke run
#
# The profile smoke run exercises the compiled plan/session path end to
# end (1 rep per arm); it self-skips when `make artifacts` has not been
# run, so ci.sh works in artifact-less environments too.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo fmt --check"
cargo fmt --check

echo "== bench smoke: profile (1 rep)"
cargo bench --bench profile -- --reps 1

echo "ci.sh: all green"
