#!/usr/bin/env bash
# Tier-1 verification in one command (also: `make ci`).
#
#   build (release) -> tests -> docs -> formatting -> clippy
#   -> bench smoke runs
#
# The netspec suite pins the NetSpec IR: BKW1->legacy-spec
# equivalence, BKW2 writer/reader round trips, and randomized
# topologies bit-identical to the unfused oracle; the custom_net
# example drives the same path end to end (builder -> BKW2 file ->
# xnor/auto plan -> serve), all artifact-free.
# The docs step denies rustdoc warnings, so missing public-item docs
# (lib.rs sets #![warn(missing_docs)]) and broken intra-doc links fail
# CI.  The profile smoke run exercises the compiled plan/session path
# end to end (1 rep per arm); it self-skips when `make artifacts` has
# not been run, so ci.sh works in artifact-less environments too.  The
# ablation smoke run (--quick) exercises every xnor kernel impl — incl.
# the SIMD tiers, tiled threading, and Auto dispatch — on real layer
# shapes; the batching smoke run (--quick) drives the replica pool end
# to end on a synthetic model.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== spec IR: BKW round-trip + randomized-topology property tests"
cargo test -q --test netspec

echo "== example: custom_net (NetSpec end to end, artifact-free)"
cargo run --release --example custom_net

echo "== cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== bench smoke: kernel ablation (--quick)"
cargo bench --bench ablation -- --quick

echo "== bench smoke: profile (1 rep)"
cargo bench --bench profile -- --reps 1

echo "== bench smoke: replica batching (--quick)"
cargo bench --bench batching -- --quick

echo "ci.sh: all green"
