//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md §9
//! calls out: word width (u32 vs u64), register blocking, threading, and
//! naive-vs-blocked float gemm.

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{pack_rows, xnor_gemm, XnorImpl};
use bitkernel::gemm::{gemm_blocked, gemm_naive};
use bitkernel::utils::Rng;

const SHAPES: [(&str, usize, usize, usize); 3] = [
    ("conv2 (128x1152x1024)", 128, 1152, 1024),
    ("conv6 (512x4608x64)", 512, 4608, 64),
    ("fc1 b8 (1024x8192x8)", 1024, 8192, 8),
];

fn main() {
    let mut rng = Rng::new(17);

    // --- xnor implementation ladder ------------------------------------------
    let mut table = Table::new(
        "xnor-gemm implementation ablation (ms; speedup vs scalar32)",
        &["layer", "scalar32", "word64", "blocked", "blocked2x4",
          "threaded2", "best speedup"],
    );
    for (name, d, k, n) in SHAPES {
        let wp = pack_rows(&rng.sign_vec(d * k), d, k);
        let xp = pack_rows(&rng.sign_vec(n * k), n, k);
        let mut out = vec![0i32; d * n];
        let mut times = Vec::new();
        for imp in [
            XnorImpl::Scalar,
            XnorImpl::Word64,
            XnorImpl::Blocked,
            XnorImpl::Blocked2x4,
            XnorImpl::Threaded(2),
        ] {
            let m = bench(&imp.name(), 0.3, 3, 1.0, || {
                xnor_gemm(&wp, &xp, &mut out, imp);
            });
            times.push(m.mean_s());
        }
        let best = times[1..].iter().cloned().fold(f64::INFINITY, f64::min);
        table.row(&[
            name.to_string(),
            format!("{:.3}", times[0] * 1e3),
            format!("{:.3}", times[1] * 1e3),
            format!("{:.3}", times[2] * 1e3),
            format!("{:.3}", times[3] * 1e3),
            format!("{:.3}", times[4] * 1e3),
            format!("{:.2}x", times[0] / best),
        ]);
    }
    table.print();
    println!("(testbed has 1 CPU core: threaded2 ~ blocked is expected; \
              the ablation exists for multi-core hosts)");

    // --- float gemm ladder -----------------------------------------------------
    let mut table = Table::new(
        "float gemm ablation (control naive vs optimized blocked, ms)",
        &["layer", "naive", "blocked", "speedup"],
    );
    for (name, d, k, n) in SHAPES {
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut out = vec![0.0f32; d * n];
        let mn = bench("naive", 0.3, 3, 1.0, || {
            gemm_naive(&a, &bt, &mut out, d, k, n);
        });
        let mb = bench("blocked", 0.3, 3, 1.0, || {
            gemm_blocked(&a, &bt, &mut out, d, k, n);
        });
        table.row(&[
            name.to_string(),
            format!("{:.3}", mn.mean_s() * 1e3),
            format!("{:.3}", mb.mean_s() * 1e3),
            format!("{:.2}x", mn.mean_s() / mb.mean_s()),
        ]);
    }
    table.print();

    // --- arithmetic-intensity summary (paper §6) -------------------------------
    let (_, d, k, n) = SHAPES[0];
    let wp = pack_rows(&rng.sign_vec(d * k), d, k);
    let xp = pack_rows(&rng.sign_vec(n * k), n, k);
    let mut iout = vec![0i32; d * n];
    let a = rng.sign_vec(d * k);
    let bt = rng.sign_vec(n * k);
    let mut fout = vec![0.0f32; d * n];
    let mx = bench("xnor", 0.5, 3, 1.0, || {
        xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Blocked);
    });
    let mc = bench("naive", 0.5, 3, 1.0, || {
        gemm_naive(&a, &bt, &mut fout, d, k, n);
    });
    let macs = (d * k * n) as f64;
    println!(
        "\npaper §6 check (conv2 shape): measured speedup {:.1}x vs the \
         32x instruction-count bound;\n  xnor: {:.2} G-MAC-equiv/s, naive \
         f32: {:.2} G-MAC/s",
        mc.mean_s() / mx.mean_s(),
        macs / mx.mean_s() / 1e9,
        macs / mc.mean_s() / 1e9
    );
}
