//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md §9
//! calls out: word width (u32 vs u64), register blocking, threading,
//! naive-vs-blocked float gemm, and the fused `bn_sign_pack` layer
//! epilogue of the plan/session path.

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{pack_rows, pack_rows_from, xnor_gemm, XnorImpl};
use bitkernel::gemm::{gemm_blocked, gemm_naive};
use bitkernel::nn::fuse::bn_sign_pack_rows_i32;
use bitkernel::tensor::PackedMatrix;
use bitkernel::utils::Rng;

const SHAPES: [(&str, usize, usize, usize); 3] = [
    ("conv2 (128x1152x1024)", 128, 1152, 1024),
    ("conv6 (512x4608x64)", 512, 4608, 64),
    ("fc1 b8 (1024x8192x8)", 1024, 8192, 8),
];

fn main() {
    let mut rng = Rng::new(17);

    // --- xnor implementation ladder ------------------------------------------
    let mut table = Table::new(
        "xnor-gemm implementation ablation (ms; speedup vs scalar32)",
        &["layer", "scalar32", "word64", "blocked", "blocked2x4",
          "threaded2", "best speedup"],
    );
    for (name, d, k, n) in SHAPES {
        let wp = pack_rows(&rng.sign_vec(d * k), d, k);
        let xp = pack_rows(&rng.sign_vec(n * k), n, k);
        let mut out = vec![0i32; d * n];
        let mut times = Vec::new();
        for imp in [
            XnorImpl::Scalar,
            XnorImpl::Word64,
            XnorImpl::Blocked,
            XnorImpl::Blocked2x4,
            XnorImpl::Threaded(2),
        ] {
            let m = bench(&imp.name(), 0.3, 3, 1.0, || {
                xnor_gemm(&wp, &xp, &mut out, imp);
            });
            times.push(m.mean_s());
        }
        let best = times[1..].iter().cloned().fold(f64::INFINITY, f64::min);
        table.row(&[
            name.to_string(),
            format!("{:.3}", times[0] * 1e3),
            format!("{:.3}", times[1] * 1e3),
            format!("{:.3}", times[2] * 1e3),
            format!("{:.3}", times[3] * 1e3),
            format!("{:.3}", times[4] * 1e3),
            format!("{:.2}x", times[0] / best),
        ]);
    }
    table.print();
    println!("(testbed has 1 CPU core: threaded2 ~ blocked is expected; \
              the ablation exists for multi-core hosts)");

    // --- float gemm ladder -----------------------------------------------------
    let mut table = Table::new(
        "float gemm ablation (control naive vs optimized blocked, ms)",
        &["layer", "naive", "blocked", "speedup"],
    );
    for (name, d, k, n) in SHAPES {
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut out = vec![0.0f32; d * n];
        let mn = bench("naive", 0.3, 3, 1.0, || {
            gemm_naive(&a, &bt, &mut out, d, k, n);
        });
        let mb = bench("blocked", 0.3, 3, 1.0, || {
            gemm_blocked(&a, &bt, &mut out, d, k, n);
        });
        table.row(&[
            name.to_string(),
            format!("{:.3}", mn.mean_s() * 1e3),
            format!("{:.3}", mb.mean_s() * 1e3),
            format!("{:.2}x", mn.mean_s() / mb.mean_s()),
        ]);
    }
    table.print();

    // --- fused bn_sign_pack epilogue (plan/session hot path) -------------------
    // The xnor arm's fc boundary: gemm i32 [D, B] + folded BN -> the next
    // layer's packed rows.  Unfused = the legacy engine's three passes
    // (transpose to f32 rows, bn affine in place, pack rows), buffers
    // preallocated here so the comparison is pure compute; fused = one
    // pass, no float rows ever materialized.
    let mut table = Table::new(
        "fc epilogue: unfused (transpose, bn, pack) vs fused bn_sign_pack (ms)",
        &["layer", "unfused", "fused", "speedup"],
    );
    for (name, d, b) in [("fc1 b8 (1024x8)", 1024usize, 8usize),
                         ("fc2 b32 (1024x32)", 1024, 32)] {
        let gemm: Vec<i32> =
            (0..d * b).map(|i| (i % 65) as i32 - 32).collect();
        let a = rng.normal_vec(d);
        let bias = rng.normal_vec(d);
        let mut rows = vec![0.0f32; b * d];
        let mut packed = PackedMatrix::zeros(b, d);
        let mu = bench("unfused", 0.2, 3, 1.0, || {
            // pass 1: transpose [D, B] i32 -> [B, D] f32 (linear())
            for di in 0..d {
                for bi in 0..b {
                    rows[bi * d + di] = gemm[di * b + bi] as f32;
                }
            }
            // pass 2: bn affine in place (bn_affine_rows)
            for bi in 0..b {
                for (di, v) in rows[bi * d..(bi + 1) * d]
                    .iter_mut()
                    .enumerate()
                {
                    *v = a[di] * *v + bias[di];
                }
            }
            // pass 3: sign + pack (next layer's pack_rows)
            pack_rows_from(&rows, &mut packed);
        });
        let mf = bench("fused", 0.2, 3, 1.0, || {
            bn_sign_pack_rows_i32(&gemm, d, b, &a, &bias, &mut packed);
        });
        table.row(&[
            name.to_string(),
            format!("{:.4}", mu.mean_s() * 1e3),
            format!("{:.4}", mf.mean_s() * 1e3),
            format!("{:.2}x", mu.mean_s() / mf.mean_s()),
        ]);
    }
    table.print();

    // --- arithmetic-intensity summary (paper §6) -------------------------------
    let (_, d, k, n) = SHAPES[0];
    let wp = pack_rows(&rng.sign_vec(d * k), d, k);
    let xp = pack_rows(&rng.sign_vec(n * k), n, k);
    let mut iout = vec![0i32; d * n];
    let a = rng.sign_vec(d * k);
    let bt = rng.sign_vec(n * k);
    let mut fout = vec![0.0f32; d * n];
    let mx = bench("xnor", 0.5, 3, 1.0, || {
        xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Blocked);
    });
    let mc = bench("naive", 0.5, 3, 1.0, || {
        gemm_naive(&a, &bt, &mut fout, d, k, n);
    });
    let macs = (d * k * n) as f64;
    println!(
        "\npaper §6 check (conv2 shape): measured speedup {:.1}x vs the \
         32x instruction-count bound;\n  xnor: {:.2} G-MAC-equiv/s, naive \
         f32: {:.2} G-MAC/s",
        mc.mean_s() / mx.mean_s(),
        macs / mx.mean_s() / 1e9,
        macs / mc.mean_s() / 1e9
    );
}
