//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md §9
//! calls out: word width (u32 vs u64), register blocking, the SIMD/wide
//! tiers, 2-D tiled threading, shape-aware `Auto`, naive-vs-SIMD float
//! gemm, and the fused `bn_sign_pack` layer epilogue of the plan/session
//! path.
//!
//! Flags:
//! * `--quick`        — tiny budgets (the `scripts/ci.sh` smoke run)
//! * `--json <path>`  — also emit per-impl GiOP/s for every layer shape
//!   as JSON (the `make bench` perf-trajectory artifact, BENCH_2.json)

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{pack_rows, pack_rows_from, simd_tier, xnor_gemm,
                        XnorImpl};
use bitkernel::gemm::{gemm_blocked, gemm_naive, gemm_simd};
use bitkernel::model::{EngineKernel, NetSpec, QuantScheme};
use bitkernel::nn::fuse::bn_sign_pack_rows_i32;
use bitkernel::tensor::{PackedMatrix, Tensor};
use bitkernel::testing::synthetic_engine_spec;
use bitkernel::utils::Rng;

/// Table-2 layer gemm shapes, plus the small-D acceptance shape for the
/// SIMD + 2-D-tiling work (a quarter-scale conv3 at batch 16: D=64 is
/// where row-only threading stopped scaling).
const SHAPES: [(&str, usize, usize, usize); 4] = [
    ("conv2 (128x1152x1024)", 128, 1152, 1024),
    ("conv3q (64x288x1024)", 64, 288, 1024),
    ("conv6 (512x4608x64)", 512, 4608, 64),
    ("fc1 b8 (1024x8192x8)", 1024, 8192, 8),
];

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg("--json");
    let (budget, min_iters) = if quick { (0.02, 1) } else { (0.3, 3) };
    let mut rng = Rng::new(17);

    // --- xnor implementation ladder ------------------------------------------
    let impls: Vec<XnorImpl> = {
        let mut v = XnorImpl::ALL_SINGLE.to_vec();
        v.push(XnorImpl::Threaded(2));
        let t = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if t > 2 {
            v.push(XnorImpl::Threaded(t));
        }
        v.push(XnorImpl::Auto);
        v
    };
    let headers: Vec<String> = std::iter::once("layer".to_string())
        .chain(impls.iter().map(|i| i.name().into_owned()))
        .chain(["best speedup".to_string()])
        .collect();
    let header_refs: Vec<&str> =
        headers.iter().map(String::as_str).collect();
    let mut table = Table::new(
        &format!(
            "xnor-gemm implementation ablation (ms; speedup vs scalar32; \
             simd tier: {})",
            simd_tier()
        ),
        &header_refs,
    );
    // (layer, d, k, n, per-impl mean seconds) for the JSON report and
    // the acceptance checks.
    let mut measured: Vec<(&str, usize, usize, usize, Vec<f64>)> =
        Vec::new();
    for (name, d, k, n) in SHAPES {
        let wp = pack_rows(&rng.sign_vec(d * k), d, k);
        let xp = pack_rows(&rng.sign_vec(n * k), n, k);
        let mut out = vec![0i32; d * n];
        let mut times = Vec::new();
        for &imp in &impls {
            let m = bench(&imp.name(), budget, min_iters, 1.0, || {
                xnor_gemm(&wp, &xp, &mut out, imp);
            });
            times.push(m.mean_s());
        }
        let best =
            times[1..].iter().cloned().fold(f64::INFINITY, f64::min);
        let mut row: Vec<String> = vec![name.to_string()];
        row.extend(times.iter().map(|t| format!("{:.3}", t * 1e3)));
        row.push(format!("{:.2}x", times[0] / best));
        table.row(&row);
        measured.push((name, d, k, n, times));
    }
    table.print();

    // --- acceptance checks (informational: perf varies per host) -------------
    let blocked_at = impls
        .iter()
        .position(|i| *i == XnorImpl::Blocked)
        .unwrap();
    let simd_at =
        impls.iter().position(|i| *i == XnorImpl::Simd).unwrap();
    let auto_at =
        impls.iter().position(|i| *i == XnorImpl::Auto).unwrap();
    for (name, _, _, n, times) in &measured {
        if name.starts_with("conv3q") && *n >= 1024 {
            let speedup = times[blocked_at] / times[simd_at];
            println!(
                "acceptance: simd vs blocked on {name}: {:.2}x ({})",
                speedup,
                if speedup >= 2.0 { "PASS >= 2x" } else { "below 2x" }
            );
        }
        // Auto within 10% of the best single-threaded impl everywhere.
        let best_single = XnorImpl::ALL_SINGLE
            .iter()
            .map(|i| times[impls.iter().position(|x| x == i).unwrap()])
            .fold(f64::INFINITY, f64::min);
        let ratio = times[auto_at] / best_single;
        println!(
            "acceptance: auto vs best-single on {name}: {:.2} ({})",
            ratio,
            if ratio <= 1.1 { "PASS <= 1.10" } else { "over budget" }
        );
    }

    // --- float gemm ladder -----------------------------------------------------
    let mut table = Table::new(
        "float gemm ablation (control naive vs blocked vs simd, ms)",
        &["layer", "naive", "blocked", "simd", "speedup (naive/simd)"],
    );
    for (name, d, k, n) in SHAPES {
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut out = vec![0.0f32; d * n];
        let mn = bench("naive", budget, min_iters, 1.0, || {
            gemm_naive(&a, &bt, &mut out, d, k, n);
        });
        let mb = bench("blocked", budget, min_iters, 1.0, || {
            gemm_blocked(&a, &bt, &mut out, d, k, n);
        });
        let ms = bench("simd", budget, min_iters, 1.0, || {
            gemm_simd(&a, &bt, &mut out, d, k, n);
        });
        table.row(&[
            name.to_string(),
            format!("{:.3}", mn.mean_s() * 1e3),
            format!("{:.3}", mb.mean_s() * 1e3),
            format!("{:.3}", ms.mean_s() * 1e3),
            format!("{:.2}x", mn.mean_s() / ms.mean_s()),
        ]);
    }
    table.print();

    // --- fused bn_sign_pack epilogue (plan/session hot path) -------------------
    // The xnor arm's fc boundary: gemm i32 [D, B] + folded BN -> the next
    // layer's packed rows.  Unfused = the legacy engine's three passes
    // (transpose to f32 rows, bn affine in place, pack rows), buffers
    // preallocated here so the comparison is pure compute; fused = one
    // pass, no float rows ever materialized.
    let mut table = Table::new(
        "fc epilogue: unfused (transpose, bn, pack) vs fused bn_sign_pack (ms)",
        &["layer", "unfused", "fused", "speedup"],
    );
    for (name, d, b) in [("fc1 b8 (1024x8)", 1024usize, 8usize),
                         ("fc2 b32 (1024x32)", 1024, 32)] {
        let gemm: Vec<i32> =
            (0..d * b).map(|i| (i % 65) as i32 - 32).collect();
        let a = rng.normal_vec(d);
        let bias = rng.normal_vec(d);
        let mut rows = vec![0.0f32; b * d];
        let mut packed = PackedMatrix::zeros(b, d);
        let mu = bench("unfused", budget, min_iters, 1.0, || {
            // pass 1: transpose [D, B] i32 -> [B, D] f32 (linear())
            for di in 0..d {
                for bi in 0..b {
                    rows[bi * d + di] = gemm[di * b + bi] as f32;
                }
            }
            // pass 2: bn affine in place (bn_affine_rows)
            for bi in 0..b {
                for (di, v) in rows[bi * d..(bi + 1) * d]
                    .iter_mut()
                    .enumerate()
                {
                    *v = a[di] * *v + bias[di];
                }
            }
            // pass 3: sign + pack (next layer's pack_rows)
            pack_rows_from(&rows, &mut packed);
        });
        let mf = bench("fused", budget, min_iters, 1.0, || {
            bn_sign_pack_rows_i32(&gemm, d, b, &a, &bias, &mut packed);
        });
        table.row(&[
            name.to_string(),
            format!("{:.4}", mu.mean_s() * 1e3),
            format!("{:.4}", mf.mean_s() * 1e3),
            format!("{:.2}x", mu.mean_s() / mf.mean_s()),
        ]);
    }
    table.print();

    // --- quantization-scheme ablation (plan/session end to end) ----------------
    // One topology lowered under each scheme, batch-8 forward on the
    // Auto plan: sign_sign is the baseline; xnor_alpha adds the α
    // multiply to the epilogues, ternary_weight popcounts a second
    // weight plane, binary_weight runs the float gemm arm outright.
    let mut table = Table::new(
        "quantization-scheme ablation (batch-8 forward, ms; vs sign_sign)",
        &["scheme", "ms", "vs sign_sign"],
    );
    let mut base_ms = None;
    for scheme in QuantScheme::ALL {
        let spec = NetSpec::builder((3, 16, 16))
            .conv(16, 3)
            .pool()
            .conv(24, 3)
            .linear(64)
            .linear(10)
            .scheme(scheme)
            .build()
            .expect("scheme ablation spec");
        let engine = synthetic_engine_spec(&spec, 77);
        let mut session = engine
            .plan(EngineKernel::Xnor(XnorImpl::Auto), 8)
            .expect("scheme ablation plan")
            .session();
        let x = Tensor::new(vec![8, 3, 16, 16],
                            rng.normal_vec(8 * 3 * 16 * 16));
        let m = bench(scheme.name(), budget, min_iters, 1.0, || {
            let _ = session.run(&x);
        });
        let ms = m.mean_s();
        let base = *base_ms.get_or_insert(ms);
        table.row(&[
            scheme.name().to_string(),
            format!("{:.3}", ms * 1e3),
            format!("{:.2}x", ms / base),
        ]);
    }
    table.print();

    // --- arithmetic-intensity summary (paper §6) -------------------------------
    let (_, d, k, n) = SHAPES[0];
    let wp = pack_rows(&rng.sign_vec(d * k), d, k);
    let xp = pack_rows(&rng.sign_vec(n * k), n, k);
    let mut iout = vec![0i32; d * n];
    let a = rng.sign_vec(d * k);
    let bt = rng.sign_vec(n * k);
    let mut fout = vec![0.0f32; d * n];
    let mx = bench("xnor", budget, min_iters, 1.0, || {
        xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Simd);
    });
    let mc = bench("naive", budget, min_iters, 1.0, || {
        gemm_naive(&a, &bt, &mut fout, d, k, n);
    });
    let macs = (d * k * n) as f64;
    println!(
        "\npaper §6 check (conv2 shape): measured speedup {:.1}x vs the \
         32x instruction-count bound;\n  xnor: {:.2} G-MAC-equiv/s, naive \
         f32: {:.2} G-MAC/s",
        mc.mean_s() / mx.mean_s(),
        macs / mx.mean_s() / 1e9,
        macs / mc.mean_s() / 1e9
    );

    // --- JSON perf-trajectory artifact (make bench -> BENCH_2.json) ------------
    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"xnor-gemm ablation\",\n");
        out.push_str(&format!("  \"simd_tier\": \"{}\",\n", simd_tier()));
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str("  \"shapes\": [\n");
        for (si, (name, d, k, n, times)) in measured.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"layer\": \"{name}\", \"d\": {d}, \"k\": {k}, \
                 \"n\": {n}, \"impls\": [\n"
            ));
            for (ii, (imp, t)) in impls.iter().zip(times).enumerate() {
                // 1 MAC-equivalent = 1 xnor+popcount bit op; report
                // 2*d*k*n ops (mul+add) per gemm, in GiOP/s.
                let giops = 2.0 * (*d * *k * *n) as f64 / t / 1e9;
                out.push_str(&format!(
                    "      {{\"impl\": \"{}\", \"ms\": {:.6}, \
                     \"giop_s\": {:.3}}}{}\n",
                    imp.name(),
                    t * 1e3,
                    giops,
                    if ii + 1 < impls.len() { "," } else { "" }
                ));
            }
            out.push_str(&format!(
                "    ]}}{}\n",
                if si + 1 < measured.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out).expect("write json report");
        eprintln!("wrote {path}");
    }
}
