//! `cargo bench --bench profile` — per-layer wall-time breakdown of the
//! native engine (the §Perf profiling tool for the L3 hot path).

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let weights = std::env::args()
        .skip_while(|a| a != "--weights")
        .nth(1)
        .unwrap_or_else(|| "full".into());
    let engine = BnnEngine::load(dir.join(format!("weights_{weights}.bkw")))
        .unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let x = ds.normalized(0, 1);

    let arms = [
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Optimized,
        EngineKernel::Control,
    ];
    // Average over a few runs (after warmup) per arm.
    let reps = 3usize;
    let mut per_arm: Vec<Vec<(String, f64)>> = Vec::new();
    for &kernel in &arms {
        let _ = engine.forward_profiled(&x, kernel); // warmup
        let mut acc: Vec<(String, f64)> = Vec::new();
        for _ in 0..reps {
            let (_, stages) = engine.forward_profiled(&x, kernel);
            if acc.is_empty() {
                acc = stages;
            } else {
                for (a, s) in acc.iter_mut().zip(stages) {
                    a.1 += s.1;
                }
            }
        }
        for a in &mut acc {
            a.1 /= reps as f64;
        }
        per_arm.push(acc);
    }

    let mut table = Table::new(
        &format!("Per-layer breakdown, {weights} model, batch 1 (ms)"),
        &["stage", "xnor", "optimized", "control", "xnor share"],
    );
    let xnor_total: f64 = per_arm[0].iter().map(|(_, t)| t).sum();
    for i in 0..per_arm[0].len() {
        table.row(&[
            per_arm[0][i].0.clone(),
            format!("{:.3}", per_arm[0][i].1 * 1e3),
            format!("{:.3}", per_arm[1][i].1 * 1e3),
            format!("{:.3}", per_arm[2][i].1 * 1e3),
            format!("{:.0}%", 100.0 * per_arm[0][i].1 / xnor_total),
        ]);
    }
    for (arm, stages) in arms.iter().zip(&per_arm) {
        let total: f64 = stages.iter().map(|(_, t)| t).sum();
        println!("total {}: {:.2} ms", arm.name(), total * 1e3);
    }
    table.print();
}
