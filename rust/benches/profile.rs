//! `cargo bench --bench profile` — per-op wall-time breakdown of the
//! native engine's compiled plan (the §Perf profiling tool for the L3
//! hot path).
//!
//! Each Table-2 arm compiles its own plan, so the stage list differs by
//! arm: the xnor arm shows the fused `encode` (im2col+bn+sign+pack) and
//! `bn_sign_pack` epilogue ops; the float arms show the unfused
//! im2col / gemm / pool / bn ladder.
//!
//! Flags: `--weights <set>` (default full), `--reps <n>` (default 3;
//! `scripts/ci.sh` passes 1 for a smoke run).

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let weights = arg("--weights").unwrap_or_else(|| "full".into());
    let reps: usize = arg("--reps")
        .map(|v| v.parse().expect("--reps takes a number"))
        .unwrap_or(3)
        .max(1);
    let engine = BnnEngine::load(dir.join(format!("weights_{weights}.bkw")))
        .unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let x = ds.normalized(0, 1);

    // Auto first: its stage names record the impl each xnor-gemm op
    // resolved to (e.g. `conv2:xnor-gemm[threaded8]`).
    let arms = [
        EngineKernel::Xnor(XnorImpl::Auto),
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Optimized,
        EngineKernel::Control,
    ];
    for kernel in arms {
        // Compile once; the session reuses its buffers across reps.
        let mut session = engine.plan(kernel, 1).unwrap().session();
        let _ = session.run(&x); // warmup
        let mut acc: Vec<(String, f64)> = Vec::new();
        for _ in 0..reps {
            let (_, stages) = session.run_profiled(&x);
            if acc.is_empty() {
                acc = stages;
            } else {
                for (a, s) in acc.iter_mut().zip(stages) {
                    a.1 += s.1;
                }
            }
        }
        for a in &mut acc {
            a.1 /= reps as f64;
        }
        let total: f64 = acc.iter().map(|(_, t)| t).sum();

        let mut table = Table::new(
            &format!("{} — per-op breakdown, {weights} model, batch 1",
                     kernel.name()),
            &["stage", "ms", "share"],
        );
        for (name, secs) in &acc {
            table.row(&[
                name.clone(),
                format!("{:.3}", secs * 1e3),
                format!("{:.0}%", 100.0 * secs / total),
            ]);
        }
        table.print();
        println!("total {}: {:.2} ms ({} ops)\n",
                 kernel.name(), total * 1e3, acc.len());
    }
}
