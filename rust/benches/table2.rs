//! `cargo bench --bench table2` — regenerate the paper's Table 2.
//!
//! See benchkit::table2 for the experiment definition and DESIGN.md §5
//! for the CPU/GPU column substitutions.

use bitkernel::benchkit::table2::{run, Table2Options};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping table2 bench: run `make artifacts` first");
        return;
    }
    // `cargo bench -- --quick` for a fast pass.
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = if quick {
        Table2Options {
            native_images: 4,
            native_control_images: 1,
            pjrt_batches: 1,
            ..Default::default()
        }
    } else {
        Table2Options::default()
    };
    let result = run(&dir, &opts, |line| eprintln!("{line}")).unwrap();
    println!("{}", result.render());

    // Reproduction shape checks (who wins, roughly by how much).
    assert!(result.native_speedup() > 1.5,
            "native: xnor must beat control clearly");
    if result.has_pjrt() {
        assert!(result.pjrt_speedup() > 1.0,
                "pjrt: xnor must beat the pallas control");
        let opt = result.row("PyTorch");
        let xnor = result.row("Our");
        assert!(opt.pjrt_s < xnor.pjrt_s,
                "accelerator arm: the vendor-optimized kernel stays \
                 fastest (paper's GPU ordering)");
    } else {
        eprintln!("(pjrt column skipped: built without the pjrt feature)");
    }
    println!("table2 orderings hold ✓");
}
