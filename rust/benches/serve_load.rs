//! `cargo bench --bench serve_load` — front-end load harness.
//!
//! Sweeps concurrent keep-alive connection counts against BOTH HTTP
//! front ends (blocking pool vs `--event-loop` epoll reactors) over
//! real TCP and records p50/p99/p999 latency and req/s per grid
//! point.  The backend is a fixed-cost mock so the measurement is
//! front-end-bound, not model-bound.
//!
//! The client is itself an epoll multiplexer (reusing the server's
//! public [`bitkernel::server::Epoll`] wrapper), so one thread drives
//! thousands of closed-loop connections — each connection keeps at
//! most one request outstanding.
//!
//! Flags:
//! * `--quick`        — small grid (the CI smoke run)
//! * `--json <path>`  — write the sweep rows as JSON
//!   (`make bench` emits BENCH_9.json this way)
//!
//! Grid points degrade gracefully: if the process fd limit stops the
//! client short of the target connection count, the row records how
//! many connections actually ran.  Thread-per-connection cannot hold
//! more threads than the pool, so blocking-front-end points above the
//! thread cap are skipped (that cliff is the point of the
//! comparison).  Linux-only (epoll); elsewhere the bench prints a
//! skip notice.

#[cfg(not(target_os = "linux"))]
fn main() {
    eprintln!("serve_load needs epoll (linux); skipping");
}

#[cfg(target_os = "linux")]
fn main() {
    linux::main();
}

#[cfg(target_os = "linux")]
mod linux {
    use std::collections::BTreeMap;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    use bitkernel::benchkit::Table;
    use bitkernel::coordinator::{
        Backend, BatcherConfig, MockBackend, Router, RouterConfig,
    };
    use bitkernel::server::{
        serve, Epoll, ServeOptions, Service, EV_ET, EV_IN, EV_OUT,
    };
    use bitkernel::utils::json::Json;
    use bitkernel::utils::timer::percentile;
    use bitkernel::utils::Stopwatch;

    /// Blocking front end: thread-per-connection stops being viable
    /// past this; larger grid points run event-loop only.
    const BLOCKING_THREAD_CAP: usize = 1024;

    fn arg(name: &str) -> Option<String> {
        std::env::args().skip_while(|a| a != name).nth(1)
    }

    /// One measured grid point.
    struct Row {
        front_end: &'static str,
        target_conns: usize,
        conns: usize,
        requests: usize,
        req_per_s: f64,
        p50_ms: f64,
        p99_ms: f64,
        p999_ms: f64,
        lost: usize,
    }

    impl Row {
        fn to_json(&self) -> Json {
            Json::obj(vec![
                ("front_end", Json::Str(self.front_end.to_string())),
                ("target_conns", Json::Num(self.target_conns as f64)),
                ("conns", Json::Num(self.conns as f64)),
                ("requests", Json::Num(self.requests as f64)),
                ("req_per_s", Json::Num(self.req_per_s)),
                ("p50_ms", Json::Num(self.p50_ms)),
                ("p99_ms", Json::Num(self.p99_ms)),
                ("p999_ms", Json::Num(self.p999_ms)),
                ("lost", Json::Num(self.lost as f64)),
            ])
        }
    }

    /// Mock 3x32x32/10 service: 1 ms per batch, 4 replicas — cheap
    /// and uniform, so the front ends are what differ.
    fn mock_service() -> Arc<Service> {
        let mut routers = BTreeMap::new();
        routers.insert(
            "m".to_string(),
            Router::start(
                |_| {
                    Ok(Box::new(MockBackend::new(8, 1))
                        as Box<dyn Backend>)
                },
                RouterConfig {
                    // Above the largest grid point: a closed-loop
                    // client never sees 429 from admission, so every
                    // non-200 is a front-end bug.
                    queue_cap: 16_384,
                    replicas: 4,
                    batcher: BatcherConfig {
                        max_batch: 8,
                        max_delay: Duration::from_millis(2),
                    },
                    ..RouterConfig::default()
                },
            )
            .unwrap(),
        );
        Arc::new(Service::new(routers, "m"))
    }

    /// One multiplexed closed-loop client connection.
    struct ClientConn {
        stream: TcpStream,
        resp_buf: Vec<u8>,
        out_buf: Vec<u8>,
        written: usize,
        writable: bool,
        /// Requests left to complete on this connection.
        remaining: usize,
        sw: Stopwatch,
        dead: bool,
    }

    impl ClientConn {
        /// Queue the next request and stamp its start time.
        fn send_next(&mut self, template: &[u8]) {
            self.out_buf.clear();
            self.out_buf.extend_from_slice(template);
            self.written = 0;
            self.sw = Stopwatch::start();
        }

        /// Push queued request bytes; false on a dead socket.
        fn flush(&mut self) -> bool {
            while self.writable && self.written < self.out_buf.len() {
                match self.stream.write(&self.out_buf[self.written..])
                {
                    Ok(0) => return false,
                    Ok(n) => self.written += n,
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        self.writable = false;
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::Interrupted => {}
                    Err(_) => return false,
                }
            }
            true
        }

        /// Drain readable bytes; false on a dead socket.
        fn drain_read(&mut self) -> bool {
            let mut chunk = [0u8; 8192];
            loop {
                match self.stream.read(&mut chunk) {
                    Ok(0) => return false,
                    Ok(n) => {
                        self.resp_buf.extend_from_slice(&chunk[..n])
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        return true
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::Interrupted => {}
                    Err(_) => return false,
                }
            }
        }

        /// If a full response is buffered, consume it and return its
        /// status code.
        fn take_response(&mut self) -> Option<u16> {
            let head_end = self
                .resp_buf
                .windows(4)
                .position(|w| w == b"\r\n\r\n")?;
            let head =
                String::from_utf8_lossy(&self.resp_buf[..head_end]);
            let mut len = 0usize;
            for line in head.lines().skip(1) {
                let lower = line.to_ascii_lowercase();
                if let Some(v) =
                    lower.strip_prefix("content-length:")
                {
                    len = v.trim().parse().unwrap_or(0);
                }
            }
            let total = head_end + 4 + len;
            if self.resp_buf.len() < total {
                return None;
            }
            let status: u16 = head
                .lines()
                .next()
                .and_then(|l| l.split(' ').nth(1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            self.resp_buf.drain(..total);
            Some(status)
        }
    }

    /// Drive `target` keep-alive connections, `reqs_per_conn` each,
    /// against `addr` from one epoll-multiplexed thread.  Returns
    /// (actual conns, latencies ms, lost requests, wall seconds).
    fn drive(
        addr: std::net::SocketAddr,
        target: usize,
        reqs_per_conn: usize,
    ) -> (usize, Vec<f64>, usize, f64) {
        let body = vec![7u8; 3 * 32 * 32];
        let mut template = format!(
            "POST /classify HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        template.extend_from_slice(&body);

        let epoll = Epoll::new().expect("client epoll");
        let mut conns: Vec<ClientConn> = Vec::with_capacity(target);
        for i in 0..target {
            let stream = match TcpStream::connect(addr) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!(
                        "  (capped at {i} connections: {e} — \
                         raise the fd limit for the full sweep)"
                    );
                    break;
                }
            };
            stream.set_nonblocking(true).unwrap();
            epoll
                .add(
                    stream.as_raw_fd(),
                    EV_IN | EV_OUT | EV_ET,
                    i as u64,
                )
                .unwrap();
            conns.push(ClientConn {
                stream,
                resp_buf: Vec::new(),
                out_buf: Vec::new(),
                written: 0,
                writable: true,
                remaining: reqs_per_conn,
                sw: Stopwatch::start(),
                dead: false,
            });
        }

        let mut latencies =
            Vec::with_capacity(conns.len() * reqs_per_conn);
        let mut lost = 0usize;
        let sw = Stopwatch::start();
        for c in conns.iter_mut() {
            c.send_next(&template);
            if !c.flush() {
                c.dead = true;
                lost += c.remaining;
            }
        }
        let mut outstanding =
            conns.iter().filter(|c| !c.dead).count();
        let mut events: Vec<(u32, u64)> = Vec::new();
        // Generous stall guard: a closed-loop request against a mock
        // backend resolves in milliseconds; minutes of silence means
        // requests were genuinely lost.
        let deadline_s = 180.0;
        while outstanding > 0 {
            if sw.elapsed_secs() > deadline_s {
                for c in conns.iter().filter(|c| !c.dead) {
                    lost += c.remaining;
                }
                eprintln!("  (stalled: {lost} requests unanswered)");
                break;
            }
            epoll.wait(&mut events, 200).expect("client epoll wait");
            for &(ev, token) in &events {
                let c = &mut conns[token as usize];
                if c.dead {
                    continue;
                }
                if ev & EV_OUT != 0 {
                    c.writable = true;
                }
                let mut alive = true;
                if ev & EV_IN != 0 {
                    alive = c.drain_read();
                }
                alive = alive && c.flush();
                while alive {
                    let Some(status) = c.take_response() else {
                        break;
                    };
                    assert_eq!(status, 200, "request failed");
                    latencies.push(c.sw.elapsed_ms());
                    c.remaining -= 1;
                    if c.remaining == 0 {
                        // Finished: flag it so a later event on this
                        // socket (e.g. the server closing it) cannot
                        // double-decrement `outstanding`.
                        c.dead = true;
                        outstanding -= 1;
                        break;
                    }
                    c.send_next(&template);
                    alive = c.flush();
                }
                if !alive {
                    c.dead = true;
                    lost += c.remaining;
                    outstanding -= 1;
                }
            }
        }
        (conns.len(), latencies, lost, sw.elapsed_secs())
    }

    pub fn main() {
        let quick = std::env::args().any(|a| a == "--quick");
        let json_path = arg("--json");
        let grid: &[usize] = if quick {
            &[64, 256, 1024]
        } else {
            &[64, 256, 1024, 4096, 10_000]
        };
        let reqs_per_conn = if quick { 2 } else { 4 };

        let mut table = Table::new(
            "Front-end sweep (mock backend, closed-loop keep-alive \
             clients, 1 req outstanding per connection)",
            &["front end", "conns", "req/s", "p50 ms", "p99 ms",
              "p999 ms", "lost"],
        );
        let mut rows: Vec<Row> = Vec::new();
        for &(front_end, event_loop) in
            &[("blocking", false), ("event-loop", true)]
        {
            for &target in grid {
                if !event_loop && target > BLOCKING_THREAD_CAP {
                    println!(
                        "(skipping blocking front end at {target} \
                         conns: thread-per-connection caps at \
                         {BLOCKING_THREAD_CAP} threads)"
                    );
                    continue;
                }
                let service = mock_service();
                let stop = Arc::new(AtomicBool::new(false));
                let (ready_tx, ready_rx) = std::sync::mpsc::channel();
                let svc2 = Arc::clone(&service);
                let stop2 = Arc::clone(&stop);
                let threads =
                    if event_loop { 4 } else { target.max(4) };
                let server = std::thread::spawn(move || {
                    serve(
                        svc2,
                        &ServeOptions {
                            addr: "127.0.0.1:0".into(),
                            threads,
                            max_connections: target + 64,
                            idle_timeout: Duration::from_secs(60),
                            event_loop,
                            io_threads: 2,
                        },
                        stop2,
                        Some(ready_tx),
                    )
                    .unwrap();
                });
                let addr = ready_rx
                    .recv_timeout(Duration::from_secs(15))
                    .unwrap();
                let (conns, lat, lost, wall) =
                    drive(addr, target, reqs_per_conn);
                let row = Row {
                    front_end,
                    target_conns: target,
                    conns,
                    requests: lat.len(),
                    req_per_s: lat.len() as f64 / wall.max(1e-9),
                    p50_ms: percentile(&lat, 0.50),
                    p99_ms: percentile(&lat, 0.99),
                    p999_ms: percentile(&lat, 0.999),
                    lost,
                };
                table.row(&[
                    front_end.to_string(),
                    format!("{conns}"),
                    format!("{:.0}", row.req_per_s),
                    format!("{:.2}", row.p50_ms),
                    format!("{:.2}", row.p99_ms),
                    format!("{:.2}", row.p999_ms),
                    format!("{lost}"),
                ]);
                // Acceptance: the event loop sustains the sweep with
                // zero request loss (the blocking arm is reported,
                // not gated — degrading is its expected behaviour).
                if event_loop {
                    assert_eq!(
                        lost, 0,
                        "event-loop front end lost requests at \
                         {conns} connections"
                    );
                }
                rows.push(row);
                stop.store(true, Ordering::Relaxed);
                server.join().unwrap();
            }
        }
        table.print();

        if let Some(path) = json_path {
            let json =
                Json::Arr(rows.iter().map(Row::to_json).collect());
            std::fs::write(&path, json.to_string())
                .expect("write json");
            println!("wrote {path}");
        }
    }
}
