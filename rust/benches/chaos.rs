//! `cargo bench --bench chaos` — fault-injection measurement: what do
//! replica panics cost the survivors?
//!
//! Two phases against one 4-replica router over a synthetic BNN:
//!
//! 1. **steady** — closed-loop hammer, no faults: the baseline
//!    requests/s and latency.
//! 2. **inject** — the same hammer while a driver thread arms a
//!    replica panic round-robin every few hundred batches' worth of
//!    wall time.  Panicked requests come back as typed errors and are
//!    retried by the closed loop (like QueueFull, they are the
//!    harness's own injected load); the row records the p99 cost of
//!    living through the respawns.
//!
//! The acceptance gate is **request-loss == 0 in both phases** — every
//! request ends in a reply or a typed, retryable error; a hang or an
//! untyped failure counts as LOST and fails the assert — so `make
//! ci`'s smoke run fails loudly on a supervision regression.
//!
//! Flags:
//! * `--quick`        — tiny request counts (the CI smoke run)
//! * `--json <path>`  — write the phase rows as JSON (`make bench`
//!   emits BENCH_7.json this way)

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, NativeBackend, ReplyError, RequestError,
    Router, RouterConfig, SubmitError,
};
use bitkernel::model::EngineKernel;
use bitkernel::testing::chaos::FaultPlan;
use bitkernel::testing::synthetic_engine;
use bitkernel::utils::json::Json;
use bitkernel::utils::timer::percentile;
use bitkernel::utils::{Rng, Stopwatch};

const REPLICAS: usize = 4;

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

/// Closed-loop hammer.  QueueFull and typed panic errors are retried —
/// both are the bench's own shed/injected load, and the measurement is
/// the service time the survivors see.  ANY other failure counts as
/// LOST.  Returns (wall secs, latencies ms, lost, panic replies seen).
fn drive(
    router: &Router,
    images: &[Vec<f32>],
    requests: usize,
    clients: usize,
) -> (f64, Vec<f64>, usize, usize) {
    let next = Arc::new(AtomicUsize::new(0));
    let lost = Arc::new(AtomicUsize::new(0));
    let panics = Arc::new(AtomicUsize::new(0));
    let sw = Stopwatch::start();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let next = Arc::clone(&next);
            let lost = Arc::clone(&lost);
            let panics = Arc::clone(&panics);
            handles.push(s.spawn(move || {
                let mut lat = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests {
                        return lat;
                    }
                    let img = images[i % images.len()].clone();
                    let sw = Stopwatch::start();
                    loop {
                        match router.submit_wait(img.clone()) {
                            Ok(_) => {
                                lat.push(sw.elapsed_ms());
                                break;
                            }
                            Err(RequestError::Rejected(
                                SubmitError::QueueFull,
                            )) => std::thread::yield_now(),
                            Err(RequestError::Failed(
                                ReplyError::ReplicaPanicked { .. },
                            )) => {
                                panics.fetch_add(1, Ordering::SeqCst);
                                std::thread::sleep(
                                    Duration::from_millis(1),
                                );
                            }
                            Err(_) => {
                                lost.fetch_add(1, Ordering::SeqCst);
                                break;
                            }
                        }
                    }
                }
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    (
        sw.elapsed_secs(),
        lat,
        lost.load(Ordering::SeqCst),
        panics.load(Ordering::SeqCst),
    )
}

struct PhaseRow {
    phase: &'static str,
    requests: usize,
    clients: usize,
    lost: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    panic_replies: usize,
    restarts: u64,
}

impl PhaseRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(self.phase.to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("req_per_s", Json::Num(self.req_per_s)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("panic_replies", Json::Num(self.panic_replies as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
        ])
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg("--json");
    let (requests, clients, injections) =
        if quick { (96, 4, 2) } else { (768, 8, 6) };

    let engine = synthetic_engine([8, 8, 8, 8, 8, 8, 16, 16, 10], 17);
    let plan = engine
        .plan(EngineKernel::Xnor(XnorImpl::Auto), 4)
        .unwrap();
    let router = Arc::new(
        Router::start(
            move |_replica| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 1024,
                replicas: REPLICAS,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );
    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> =
        (0..16).map(|_| rng.normal_vec(3 * 32 * 32)).collect();

    // --- phase 1: steady state (no plan installed) --------------------------
    let (wall, lat, lost, panic_replies) =
        drive(&router, &images, requests, clients);
    let steady = PhaseRow {
        phase: "steady",
        requests,
        clients,
        lost,
        req_per_s: requests as f64 / wall,
        p50_ms: percentile(&lat, 0.5),
        p99_ms: percentile(&lat, 0.99),
        panic_replies,
        restarts: 0,
    };
    assert_eq!(
        steady.panic_replies, 0,
        "no plan is installed — steady phase must see zero panics"
    );

    // --- phase 2: the same hammer under round-robin replica panics ----------
    let guard = FaultPlan::new().install();
    let stop_faults = AtomicBool::new(false);
    let (fired, (wall, lat, lost, panic_replies)) =
        std::thread::scope(|s| {
            let plan = Arc::clone(guard.plan());
            let stop = &stop_faults;
            let injector = s.spawn(move || {
                let mut fired = 0usize;
                for i in 0..injections {
                    // Always fire the first fault (so the phase
                    // measures at least one respawn) even if the
                    // hammer raced past.
                    if i > 0 && stop.load(Ordering::Relaxed) {
                        break;
                    }
                    plan.arm_panic(i % REPLICAS);
                    fired += 1;
                    std::thread::sleep(Duration::from_millis(150));
                }
                fired
            });
            let out = drive(&router, &images, requests, clients);
            stop_faults.store(true, Ordering::Relaxed);
            (injector.join().unwrap(), out)
        });
    // Let any armed-but-unfired fault and the last respawn settle
    // before reading the restart counters.
    let sw = Stopwatch::start();
    while router.healthy_replicas() < REPLICAS
        && sw.elapsed_secs() < 30.0
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(
        router.healthy_replicas(),
        REPLICAS,
        "pool never converged back to {REPLICAS} replicas"
    );
    let snap = router.metrics().snapshot();
    let inject = PhaseRow {
        phase: "inject",
        requests,
        clients,
        lost,
        req_per_s: requests as f64 / wall,
        p50_ms: percentile(&lat, 0.5),
        p99_ms: percentile(&lat, 0.99),
        panic_replies,
        restarts: snap.replicas.iter().map(|r| r.restarts).sum(),
    };
    drop(guard);
    assert!(fired > 0, "the injector must arm at least one fault");

    let rows = [steady, inject];
    let mut table = Table::new(
        &format!(
            "Panic injection under load ({requests} req, {clients} \
             clients, {REPLICAS} replicas, synthetic 3x32x32 conv net, \
             {fired} armed faults)"
        ),
        &["phase", "req/s", "p50 ms", "p99 ms", "lost",
          "panic replies", "restarts"],
    );
    for r in &rows {
        table.row(&[
            r.phase.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            format!("{}", r.lost),
            format!("{}", r.panic_replies),
            format!("{}", r.restarts),
        ]);
    }
    table.print();

    if let Some(p) = json_path {
        let json =
            Json::Arr(rows.iter().map(PhaseRow::to_json).collect());
        std::fs::write(&p, json.to_string()).unwrap();
        println!("wrote {p}");
    }

    // Acceptance: supervision must not lose a single request — every
    // submission ends in a reply or a typed, retryable error, faults
    // or no faults.
    for r in &rows {
        assert_eq!(
            r.lost, 0,
            "phase '{}' lost {} requests — supervision must keep every \
             reply typed",
            r.phase, r.lost
        );
    }
    println!(
        "acceptance: 0 lost requests across {} injected faults \
         ({} panic replies, {} restarts)",
        fired, rows[1].panic_replies, rows[1].restarts
    );
}
