//! `cargo bench --bench kernels` — per-layer gemm kernel comparison
//! (the paper's §6 discussion: measure time, don't count instructions).
//!
//! For every conv/fc gemm shape of the full-scale BNN, times the native
//! xnor kernels (blocked and SIMD tiers) vs the naive control vs the
//! blocked/SIMD float kernels, then (with `--features pjrt` and
//! artifacts present) the same shapes through the AOT PJRT executables.

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{pack_rows, simd_tier, xnor_gemm, XnorImpl};
use bitkernel::gemm::{gemm_naive, gemm_simd};
use bitkernel::utils::Rng;

/// (name, D, K, N) — gemm shapes of the full BNN at batch 1 (conv) and
/// batch 8 (fc1).
const SHAPES: [(&str, usize, usize, usize); 4] = [
    ("conv2 (128x1152x1024)", 128, 1152, 1024),
    ("conv4 (256x2304x256)", 256, 2304, 256),
    ("conv6 (512x4608x64)", 512, 4608, 64),
    ("fc1 b8 (1024x8192x8)", 1024, 8192, 8),
];

fn main() {
    let mut rng = Rng::new(7);
    let mut table = Table::new(
        &format!(
            "Native gemm kernels per BNN layer shape (ms; simd tier: {})",
            simd_tier()
        ),
        &["layer", "xnor blocked", "xnor simd", "xnor auto",
          "control (naive f32)", "simd f32 (optimized)",
          "xnor-simd vs control"],
    );
    for (name, d, k, n) in SHAPES {
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let wp = pack_rows(&a, d, k);
        let xp = pack_rows(&bt, n, k);
        let mut iout = vec![0i32; d * n];
        let mut fout = vec![0.0f32; d * n];

        let mb = bench("xnor-blocked", 0.4, 3, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Blocked);
        });
        let ms = bench("xnor-simd", 0.4, 3, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Simd);
        });
        let ma = bench("xnor-auto", 0.4, 3, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Auto);
        });
        let mc = bench("control", 0.4, 3, 1.0, || {
            gemm_naive(&a, &bt, &mut fout, d, k, n);
        });
        let mf = bench("simd-f32", 0.4, 3, 1.0, || {
            gemm_simd(&a, &bt, &mut fout, d, k, n);
        });
        table.row(&[
            name.to_string(),
            format!("{:.3}", mb.mean_s() * 1e3),
            format!("{:.3}", ms.mean_s() * 1e3),
            format!("{:.3}", ma.mean_s() * 1e3),
            format!("{:.3}", mc.mean_s() * 1e3),
            format!("{:.3}", mf.mean_s() * 1e3),
            format!("{:.1}x", mc.mean_s() / ms.mean_s()),
        ]);
        assert!(ms.mean_s() < mc.mean_s(),
                "{name}: xnor must beat naive float");
    }
    table.print();

    pjrt_section();
}

/// PJRT micro-kernel executables (needs artifacts + the pjrt feature).
#[cfg(feature = "pjrt")]
fn pjrt_section() {
    use bitkernel::runtime::Runtime;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping pjrt kernel bench: no artifacts)");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let mut table = Table::new(
        "PJRT kernel executables per layer shape (ms)",
        &["layer", "xnor (pallas)", "control (pallas f32)",
          "optimized (XLA dot)", "xnor vs control"],
    );
    let tags: Vec<&str> = {
        let mut t: Vec<&str> =
            rt.manifest.kernels.iter().map(|k| k.tag.as_str()).collect();
        t.dedup();
        t
    };
    for tag in tags {
        let mut ms = std::collections::BTreeMap::new();
        for kernel in ["xnor", "control", "optimized"] {
            let entry = rt
                .manifest
                .kernels
                .iter()
                .find(|k| k.kernel == kernel && k.tag == tag)
                .unwrap()
                .clone();
            let exe = rt.load_kernel(&entry.name).unwrap();
            let kw = entry.k.div_ceil(32);
            let (a, b) = if kernel == "xnor" {
                (
                    xla::Literal::vec1(&vec![0xAAAAAAAAu32; entry.d * kw])
                        .reshape(&[entry.d as i64, kw as i64])
                        .unwrap(),
                    xla::Literal::vec1(&vec![0x55555555u32; kw * entry.n])
                        .reshape(&[kw as i64, entry.n as i64])
                        .unwrap(),
                )
            } else {
                (
                    xla::Literal::vec1(&vec![1.0f32; entry.d * entry.k])
                        .reshape(&[entry.d as i64, entry.k as i64])
                        .unwrap(),
                    xla::Literal::vec1(&vec![-1.0f32; entry.k * entry.n])
                        .reshape(&[entry.k as i64, entry.n as i64])
                        .unwrap(),
                )
            };
            // warmup
            let _ = exe.execute::<xla::Literal>(&[a.clone(), b.clone()]).unwrap();
            let m = bench(kernel, 0.4, 3, 1.0, || {
                std::hint::black_box(
                    exe.execute::<xla::Literal>(&[a.clone(), b.clone()])
                        .unwrap(),
                );
            });
            ms.insert(kernel.to_string(), m.mean_s());
        }
        table.row(&[
            tag.to_string(),
            format!("{:.3}", ms["xnor"] * 1e3),
            format!("{:.3}", ms["control"] * 1e3),
            format!("{:.3}", ms["optimized"] * 1e3),
            format!("{:.1}x", ms["control"] / ms["xnor"]),
        ]);
    }
    table.print();
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    eprintln!("(skipping pjrt kernel bench: built without the pjrt feature)");
}
