//! `cargo bench --bench kernels` — per-layer gemm kernel comparison
//! (the paper's §6 discussion: measure time, don't count instructions).
//!
//! For every conv/fc gemm shape of the full-scale BNN, times the native
//! xnor kernels (blocked and SIMD tiers) vs the naive control vs the
//! blocked/SIMD float kernels; a second table sweeps every single-core
//! `XnorImpl` arm — including the AVX-512 VPOPCNTDQ tier — and reports
//! per-impl throughput in GiOP/s.  On hosts with real VPOPCNTDQ the
//! bench ASSERTS the avx512 arm beats the 256-bit simd arm on the
//! acceptance shape (64x288x1024); elsewhere the arm falls back and no
//! speedup is claimed.  With `--features pjrt` and artifacts present,
//! the same shapes also run through the AOT PJRT executables.
//!
//! `--quick` shrinks the measurement budget and shape set to a CI
//! smoke (the assertions still run).

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{avx512_vpopcnt_available, pack_rows, simd_tier,
                        xnor_gemm, XnorImpl};
use bitkernel::gemm::{gemm_naive, gemm_simd};
use bitkernel::utils::Rng;

/// (name, D, K, N) — gemm shapes of the full BNN at batch 1 (conv) and
/// batch 8 (fc1).
const SHAPES: [(&str, usize, usize, usize); 4] = [
    ("conv2 (128x1152x1024)", 128, 1152, 1024),
    ("conv4 (256x2304x256)", 256, 2304, 256),
    ("conv6 (512x4608x64)", 512, 4608, 64),
    ("fc1 b8 (1024x8192x8)", 1024, 8192, 8),
];

/// The acceptance shape the AVX-512 tier is gated on: k=288 (9 words)
/// exercises both the 16-word main loop remainder and the word tail.
const ACCEPT: (&str, usize, usize, usize) =
    ("accept (64x288x1024)", 64, 288, 1024);

/// Single-core arms swept by the per-impl throughput table (Auto rides
/// along to show what the heuristic picks).
const PER_IMPL: [XnorImpl; 6] = [
    XnorImpl::Blocked,
    XnorImpl::Blocked2x4,
    XnorImpl::Wide,
    XnorImpl::Simd,
    XnorImpl::Avx512,
    XnorImpl::Auto,
];

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Measurement budget: (seconds per point, repetitions).
    let (secs, reps) = if quick { (0.02, 1) } else { (0.4, 3) };
    let mut rng = Rng::new(7);

    let shapes: Vec<(&str, usize, usize, usize)> = if quick {
        vec![ACCEPT]
    } else {
        SHAPES.iter().copied().chain([ACCEPT]).collect()
    };

    let mut table = Table::new(
        &format!(
            "Native gemm kernels per BNN layer shape (ms; simd tier: {})",
            simd_tier()
        ),
        &["layer", "xnor blocked", "xnor simd", "xnor auto",
          "control (naive f32)", "simd f32 (optimized)",
          "xnor-simd vs control"],
    );
    let mut giops_table = Table::new(
        "Per-impl xnor-gemm throughput (GiOP/s; 2*D*K*N bit-ops/gemm)",
        &["layer", "blocked", "blocked2x4", "wide64", "simd",
          "avx512", "auto"],
    );

    for (name, d, k, n) in shapes {
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let wp = pack_rows(&a, d, k);
        let xp = pack_rows(&bt, n, k);
        let mut iout = vec![0i32; d * n];
        let mut fout = vec![0.0f32; d * n];

        let mb = bench("xnor-blocked", secs, reps, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Blocked);
        });
        let ms = bench("xnor-simd", secs, reps, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Simd);
        });
        let ma = bench("xnor-auto", secs, reps, 1.0, || {
            xnor_gemm(&wp, &xp, &mut iout, XnorImpl::Auto);
        });
        let mc = bench("control", secs, reps, 1.0, || {
            gemm_naive(&a, &bt, &mut fout, d, k, n);
        });
        let mf = bench("simd-f32", secs, reps, 1.0, || {
            gemm_simd(&a, &bt, &mut fout, d, k, n);
        });
        table.row(&[
            name.to_string(),
            format!("{:.3}", mb.mean_s() * 1e3),
            format!("{:.3}", ms.mean_s() * 1e3),
            format!("{:.3}", ma.mean_s() * 1e3),
            format!("{:.3}", mc.mean_s() * 1e3),
            format!("{:.3}", mf.mean_s() * 1e3),
            format!("{:.1}x", mc.mean_s() / ms.mean_s()),
        ]);
        assert!(ms.mean_s() < mc.mean_s(),
                "{name}: xnor must beat naive float");

        // Per-impl throughput sweep.  One xnor+popcount MAC covers a
        // multiply and an add of the dense gemm, so ops = 2*D*K*N —
        // the same convention the float kernels would report under.
        let ops = (2 * d * k * n) as f64;
        let mut row = vec![name.to_string()];
        let mut per_impl_s = Vec::with_capacity(PER_IMPL.len());
        for imp in PER_IMPL {
            let m = bench(&format!("impl-{}", imp.name()), secs, reps,
                          1.0, || {
                xnor_gemm(&wp, &xp, &mut iout, imp);
            });
            per_impl_s.push(m.mean_s());
            row.push(format!(
                "{:.1}",
                ops / m.mean_s() / (1u64 << 30) as f64
            ));
        }
        giops_table.row(&row);

        // Acceptance gate: on real VPOPCNTDQ hardware the 512-bit arm
        // must beat the 256-bit simd arm on the acceptance shape.  On
        // BW-only or AVX2 hosts the arm falls back (bit-identical by
        // the conformance suites) and no speedup is asserted.
        if (name, d, k, n) == ACCEPT && avx512_vpopcnt_available() {
            let t_simd = per_impl_s[3];
            let t_avx512 = per_impl_s[4];
            assert!(
                t_avx512 < t_simd,
                "avx512 ({:.3} ms) must beat simd ({:.3} ms) on {}",
                t_avx512 * 1e3,
                t_simd * 1e3,
                name
            );
        }
    }
    table.print();
    giops_table.print();

    if !quick {
        pjrt_section();
    }
}

/// PJRT micro-kernel executables (needs artifacts + the pjrt feature).
#[cfg(feature = "pjrt")]
fn pjrt_section() {
    use bitkernel::runtime::Runtime;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping pjrt kernel bench: no artifacts)");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let mut table = Table::new(
        "PJRT kernel executables per layer shape (ms)",
        &["layer", "xnor (pallas)", "control (pallas f32)",
          "optimized (XLA dot)", "xnor vs control"],
    );
    let tags: Vec<&str> = {
        let mut t: Vec<&str> =
            rt.manifest.kernels.iter().map(|k| k.tag.as_str()).collect();
        t.dedup();
        t
    };
    for tag in tags {
        let mut ms = std::collections::BTreeMap::new();
        for kernel in ["xnor", "control", "optimized"] {
            let entry = rt
                .manifest
                .kernels
                .iter()
                .find(|k| k.kernel == kernel && k.tag == tag)
                .unwrap()
                .clone();
            let exe = rt.load_kernel(&entry.name).unwrap();
            let kw = entry.k.div_ceil(32);
            let (a, b) = if kernel == "xnor" {
                (
                    xla::Literal::vec1(&vec![0xAAAAAAAAu32; entry.d * kw])
                        .reshape(&[entry.d as i64, kw as i64])
                        .unwrap(),
                    xla::Literal::vec1(&vec![0x55555555u32; kw * entry.n])
                        .reshape(&[kw as i64, entry.n as i64])
                        .unwrap(),
                )
            } else {
                (
                    xla::Literal::vec1(&vec![1.0f32; entry.d * entry.k])
                        .reshape(&[entry.d as i64, entry.k as i64])
                        .unwrap(),
                    xla::Literal::vec1(&vec![-1.0f32; entry.k * entry.n])
                        .reshape(&[entry.k as i64, entry.n as i64])
                        .unwrap(),
                )
            };
            // warmup
            let _ = exe.execute::<xla::Literal>(&[a.clone(), b.clone()]).unwrap();
            let m = bench(kernel, 0.4, 3, 1.0, || {
                std::hint::black_box(
                    exe.execute::<xla::Literal>(&[a.clone(), b.clone()])
                        .unwrap(),
                );
            });
            ms.insert(kernel.to_string(), m.mean_s());
        }
        table.row(&[
            tag.to_string(),
            format!("{:.3}", ms["xnor"] * 1e3),
            format!("{:.3}", ms["control"] * 1e3),
            format!("{:.3}", ms["optimized"] * 1e3),
            format!("{:.1}x", ms["control"] / ms["xnor"]),
        ]);
    }
    table.print();
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    eprintln!("(skipping pjrt kernel bench: built without the pjrt feature)");
}
