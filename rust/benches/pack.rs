//! `cargo bench --bench pack` — encoding cost (paper Sec. 3.1).
//!
//! The xnor pipeline pays an encode (bit-pack) pass per layer that the
//! float arms do not.  This bench measures that overhead per layer shape
//! and its share of the total xnor conv time — the paper's implicit
//! claim is that encoding is cheap relative to the gemm it accelerates.

use bitkernel::benchkit::{bench, Table};
use bitkernel::bitops::{pack_rows, pack_rows_from, xnor_gemm, XnorImpl};
use bitkernel::tensor::PackedMatrix;
use bitkernel::utils::Rng;

const SHAPES: [(&str, usize, usize, usize); 4] = [
    ("conv2 cols (1024x1152)", 128, 1152, 1024),
    ("conv4 cols (256x2304)", 256, 2304, 256),
    ("conv6 cols (64x4608)", 512, 4608, 64),
    ("fc1 act b8 (8x8192)", 1024, 8192, 8),
];

fn main() {
    let mut rng = Rng::new(3);
    let mut table = Table::new(
        "Encode (bit-pack) cost per layer (paper Sec. 3.1)",
        &["layer", "pack ms", "xnor-gemm ms", "pack share",
          "pack GB/s (f32 in)"],
    );
    for (name, d, k, n) in SHAPES {
        let cols = rng.normal_vec(n * k);
        let w = pack_rows(&rng.sign_vec(d * k), d, k);
        let mut xp = PackedMatrix::zeros(n, k);
        let mut out = vec![0i32; d * n];

        let mp = bench("pack", 0.3, 3, 1.0, || {
            pack_rows_from(&cols, &mut xp);
        });
        let mg = bench("gemm", 0.3, 3, 1.0, || {
            xnor_gemm(&w, &xp, &mut out, XnorImpl::Blocked);
        });
        let bytes_in = (n * k * 4) as f64;
        table.row(&[
            name.to_string(),
            format!("{:.3}", mp.mean_s() * 1e3),
            format!("{:.3}", mg.mean_s() * 1e3),
            format!("{:.0}%", 100.0 * mp.mean_s()
                    / (mp.mean_s() + mg.mean_s())),
            format!("{:.2}", bytes_in / mp.mean_s() / 1e9),
        ]);
    }
    table.print();

    // Allocation-free repack vs fresh allocation (hot-path design choice).
    let (_, d, k, n) = SHAPES[0];
    let cols = rng.normal_vec(n * k);
    let mut xp = PackedMatrix::zeros(n, k);
    let m_reuse = bench("reuse", 0.3, 3, 1.0, || {
        pack_rows_from(&cols, &mut xp);
    });
    let m_alloc = bench("alloc", 0.3, 3, 1.0, || {
        std::hint::black_box(pack_rows(&cols, n, k));
    });
    println!(
        "buffer reuse vs alloc (conv2 cols): {:.3} ms vs {:.3} ms ({:.2}x)",
        m_reuse.mean_s() * 1e3,
        m_alloc.mean_s() * 1e3,
        m_alloc.mean_s() / m_reuse.mean_s()
    );
    let _ = d;
}
