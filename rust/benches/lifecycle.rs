//! `cargo bench --bench lifecycle` — reload-under-load measurement.
//!
//! Two phases against one registry-mounted synthetic model:
//!
//! 1. **steady** — closed-loop hammer with no lifecycle churn: the
//!    baseline requests/s and latency through `router_for`.
//! 2. **reload** — the same hammer while the driver reloads the model
//!    from freshly rewritten weights in a loop.  Each request pins its
//!    generation's router, the swap retires the old pipeline through
//!    the lossless drain, and the row records the p99 cost of living
//!    through it.
//!
//! The acceptance gate is **request-loss == 0 in both phases** — a
//! reload may never drop a request — enforced with an assert, so `make
//! ci`'s smoke run fails loudly on a regression.
//!
//! Flags:
//! * `--quick`        — tiny request counts (the CI smoke run)
//! * `--json <path>`  — write the phase rows as JSON (`make bench`
//!   emits BENCH_6.json this way)

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    BatcherConfig, RequestError, RouterConfig, SubmitError,
};
use bitkernel::model::{EngineKernel, NetSpec};
use bitkernel::server::{ModelRegistry, ModelState, RegistryConfig};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;
use bitkernel::utils::timer::{mean, percentile};
use bitkernel::utils::{Rng, Stopwatch};

const MODEL: &str = "bench";

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn spec() -> NetSpec {
    NetSpec::builder((3, 16, 16))
        .conv(16, 3)
        .pool()
        .linear(10)
        .build()
        .unwrap()
}

fn write_model(path: &Path, seed: u64) {
    synthetic_weight_file(&spec(), seed).save(path).unwrap();
}

/// Closed-loop hammer: `clients` threads race through `requests`
/// submissions, each resolving the model through the registry (pinning
/// that request's generation) exactly like the HTTP layer.  Returns
/// (wall seconds, latencies ms, lost requests).  QueueFull is retried
/// — a closed loop measures service time, not its own shed load; any
/// other failure counts as LOST.
fn drive(
    registry: &Arc<ModelRegistry>,
    images: &[Vec<f32>],
    requests: usize,
    clients: usize,
) -> (f64, Vec<f64>, usize) {
    let next = Arc::new(AtomicUsize::new(0));
    let lost = Arc::new(AtomicUsize::new(0));
    let sw = Stopwatch::start();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let next = Arc::clone(&next);
            let lost = Arc::clone(&lost);
            handles.push(s.spawn(move || {
                let mut lat = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests {
                        return lat;
                    }
                    let img = images[i % images.len()].clone();
                    let sw = Stopwatch::start();
                    let Ok((router, _generation)) =
                        registry.router_for(MODEL)
                    else {
                        lost.fetch_add(1, Ordering::SeqCst);
                        continue;
                    };
                    loop {
                        match router.submit_wait(img.clone()) {
                            Ok(_) => {
                                lat.push(sw.elapsed_ms());
                                break;
                            }
                            Err(RequestError::Rejected(
                                SubmitError::QueueFull,
                            )) => {
                                std::thread::yield_now();
                            }
                            Err(_) => {
                                lost.fetch_add(1, Ordering::SeqCst);
                                break;
                            }
                        }
                    }
                }
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    (sw.elapsed_secs(), lat, lost.load(Ordering::SeqCst))
}

struct PhaseRow {
    phase: &'static str,
    requests: usize,
    clients: usize,
    lost: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    reloads: usize,
    reload_mean_ms: f64,
}

impl PhaseRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(self.phase.to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("req_per_s", Json::Num(self.req_per_s)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("reloads", Json::Num(self.reloads as f64)),
            ("reload_mean_ms", Json::Num(self.reload_mean_ms)),
        ])
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg("--json");
    let (requests, clients, reloads) =
        if quick { (96, 4, 3) } else { (768, 8, 8) };

    let dir = std::env::temp_dir().join(format!(
        "bk-bench-lifecycle-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.bkw");
    write_model(&path, 1);

    let registry = ModelRegistry::new(RegistryConfig {
        kernel: EngineKernel::Xnor(XnorImpl::Auto),
        max_batch: 8,
        router: RouterConfig {
            queue_cap: 1024,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(1),
            },
            ..RouterConfig::default()
        },
        max_resident: 0,
    });
    let entry = registry.mount(MODEL, &path, false).unwrap();
    let st = entry.wait_settled(Duration::from_secs(60));
    assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);

    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> =
        (0..16).map(|_| rng.normal_vec(3 * 16 * 16)).collect();

    // --- phase 1: steady state ---------------------------------------------
    let (wall, lat, lost) = drive(&registry, &images, requests, clients);
    let steady = PhaseRow {
        phase: "steady",
        requests,
        clients,
        lost,
        req_per_s: requests as f64 / wall,
        p50_ms: percentile(&lat, 0.5),
        p99_ms: percentile(&lat, 0.99),
        reloads: 0,
        reload_mean_ms: 0.0,
    };

    // --- phase 2: the same hammer under a reload loop ----------------------
    let stop_reloads = AtomicBool::new(false);
    let (reload_ms, (wall, lat, lost)) = std::thread::scope(|s| {
        let reg = Arc::clone(&registry);
        let reload_path = path.clone();
        let stop = &stop_reloads;
        let reloader = s.spawn(move || {
            let mut times = Vec::new();
            for i in 0..reloads {
                // Always run the first reload (so the phase measures
                // at least one swap) even if the hammer raced past.
                if i > 0 && stop.load(Ordering::Relaxed) {
                    break;
                }
                write_model(&reload_path, 2 + i as u64);
                let sw = Stopwatch::start();
                let entry = reg.reload(MODEL).unwrap();
                let st = entry.wait_settled(Duration::from_secs(60));
                assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);
                times.push(sw.elapsed_ms());
            }
            times
        });
        let out = drive(&registry, &images, requests, clients);
        stop_reloads.store(true, Ordering::Relaxed);
        (reloader.join().unwrap(), out)
    });
    let reload = PhaseRow {
        phase: "reload",
        requests,
        clients,
        lost,
        req_per_s: requests as f64 / wall,
        p50_ms: percentile(&lat, 0.5),
        p99_ms: percentile(&lat, 0.99),
        reloads: reload_ms.len(),
        reload_mean_ms: if reload_ms.is_empty() {
            0.0
        } else {
            mean(&reload_ms)
        },
    };
    assert!(
        reload.reloads > 0,
        "phase 2 finished before a single reload — raise the request \
         count"
    );

    let rows = [steady, reload];
    let mut table = Table::new(
        &format!(
            "Reload under load ({requests} req, {clients} clients, \
             2 replicas, synthetic 3x16x16 conv net)"
        ),
        &["phase", "req/s", "p50 ms", "p99 ms", "lost", "reloads",
          "reload mean ms"],
    );
    for r in &rows {
        table.row(&[
            r.phase.to_string(),
            format!("{:.0}", r.req_per_s),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            format!("{}", r.lost),
            format!("{}", r.reloads),
            format!("{:.1}", r.reload_mean_ms),
        ]);
    }
    table.print();

    if let Some(p) = json_path {
        let json =
            Json::Arr(rows.iter().map(PhaseRow::to_json).collect());
        std::fs::write(&p, json.to_string()).unwrap();
        println!("wrote {p}");
    }

    // Acceptance: the swap discipline must not shed a single request,
    // with or without churn.
    for r in &rows {
        assert_eq!(
            r.lost, 0,
            "phase '{}' lost {} requests — reload/drain must be \
             lossless",
            r.phase, r.lost
        );
    }
    println!(
        "acceptance: 0 lost requests across {} reloads under load",
        rows[1].reloads
    );
    let _ = std::fs::remove_dir_all(&dir);
}
