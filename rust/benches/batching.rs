//! `cargo bench --bench batching` — coordinator policy sweep:
//! throughput/latency vs (max_batch, max_delay) under closed-loop load,
//! using the trained BNN on the native xnor kernel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::benchkit::Table;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router, RouterConfig,
};
use bitkernel::data::Dataset;
use bitkernel::model::BnnEngine;
use bitkernel::utils::timer::{mean, percentile};
use bitkernel::utils::Stopwatch;

fn drive(
    router: &Router,
    ds: &Dataset,
    requests: usize,
    clients: usize,
) -> (f64, Vec<f64>) {
    let next = Arc::new(AtomicUsize::new(0));
    let sw = Stopwatch::start();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let next = Arc::clone(&next);
            handles.push(s.spawn(|| {
                let next = next;
                let mut lat = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests {
                        return lat;
                    }
                    let img = ds.normalized(i % ds.count, i % ds.count + 1);
                    let sw = Stopwatch::start();
                    router.submit_wait(img.into_data()).unwrap();
                    lat.push(sw.elapsed_ms());
                }
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    (sw.elapsed_secs(), lat)
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // --- policy sweep with the mock backend (pure coordinator cost) -----------
    let mut table = Table::new(
        "Batching policy sweep (mock backend, 2ms/batch, 256 req, 16 clients)",
        &["max_batch", "max_delay", "req/s", "p50 ms", "p99 ms",
          "mean batch"],
    );
    for (mb, delay_ms) in
        [(1, 0u64), (4, 1), (8, 2), (8, 10), (16, 2), (32, 5)]
    {
        let router = Router::start(
            move || Ok(Box::new(MockBackend::new(mb, 2)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 1024,
                batcher: BatcherConfig {
                    max_batch: mb,
                    max_delay: Duration::from_millis(delay_ms),
                },
            },
        )
        .unwrap();
        // synthetic images: mock ignores content
        let (wall, lat) = {
            let next = Arc::new(AtomicUsize::new(0));
            let requests = 256;
            let sw = Stopwatch::start();
            let lat: Vec<f64> = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for _ in 0..16 {
                    let next = Arc::clone(&next);
                    let router = &router;
                    handles.push(s.spawn(move || {
                        let mut lat = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::SeqCst);
                            if i >= requests {
                                return lat;
                            }
                            let sw = Stopwatch::start();
                            router
                                .submit_wait(vec![0.1f32; 3 * 32 * 32])
                                .unwrap();
                            lat.push(sw.elapsed_ms());
                        }
                    }));
                }
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            (sw.elapsed_secs(), lat)
        };
        let snap = router.metrics().snapshot();
        table.row(&[
            format!("{mb}"),
            format!("{delay_ms}ms"),
            format!("{:.0}", 256.0 / wall),
            format!("{:.2}", percentile(&lat, 0.5)),
            format!("{:.2}", percentile(&lat, 0.99)),
            format!("{:.2}", snap.mean_batch_size),
        ]);
    }
    table.print();

    // --- real model -------------------------------------------------------------
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping real-model batching bench: no artifacts)");
        return;
    }
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let mut table = Table::new(
        "Batching with the trained BNN (native xnor, 64 req, 8 clients)",
        &["max_batch", "req/s", "mean ms", "p99 ms", "mean batch"],
    );
    for mb in [1usize, 4, 8, 16] {
        let weights = dir.join("weights_small.bkw");
        let router = Router::start(
            move || {
                let engine = BnnEngine::load(&weights)?;
                Ok(Box::new(NativeBackend::xnor(&engine, mb)) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 256,
                batcher: BatcherConfig {
                    max_batch: mb,
                    max_delay: Duration::from_millis(3),
                },
            },
        )
        .unwrap();
        let (wall, lat) = drive(&router, &ds, 64, 8);
        let snap = router.metrics().snapshot();
        table.row(&[
            format!("{mb}"),
            format!("{:.1}", 64.0 / wall),
            format!("{:.1}", mean(&lat)),
            format!("{:.1}", percentile(&lat, 0.99)),
            format!("{:.2}", snap.mean_batch_size),
        ]);
    }
    table.print();
}
