//! `cargo bench --bench batching` — coordinator policy sweep.
//!
//! Three sections:
//!
//! 1. **Mock policy sweep** — throughput/latency vs (max_batch,
//!    max_delay) with a fixed-cost backend: pure coordinator overhead.
//! 2. **Replica scaling sweep** — the replicated-serving measurement:
//!    replicas × max_batch × max_delay under closed-loop load against a
//!    synthetic BNN (no artifacts needed), every replica minting its
//!    session from ONE shared compiled plan.  This is the table that
//!    backs the "N replicas ≈ N× requests/s" claim; `--json` writes it
//!    as `BENCH_3.json`.
//! 3. **Trained model** (skipped without `make artifacts`): the same
//!    sweep shape against the real weights.
//!
//! Flags:
//! * `--quick`        — tiny request counts (the CI smoke run)
//! * `--json <path>`  — write the replica-sweep rows as JSON
//!   (`make bench` emits BENCH_3.json this way)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::benchkit::Table;
use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router, RouterConfig,
};
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::testing::synthetic_engine;
use bitkernel::utils::json::Json;
use bitkernel::utils::timer::{mean, percentile};
use bitkernel::utils::{Rng, Stopwatch};

/// Closed-loop load: `clients` threads race through `requests`
/// submissions drawn round-robin from `images`.  Returns (wall seconds,
/// per-request latencies in ms).
fn drive(
    router: &Router,
    images: &[Vec<f32>],
    requests: usize,
    clients: usize,
) -> (f64, Vec<f64>) {
    let next = Arc::new(AtomicUsize::new(0));
    let sw = Stopwatch::start();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let next = Arc::clone(&next);
            handles.push(s.spawn(move || {
                let mut lat = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests {
                        return lat;
                    }
                    let img = images[i % images.len()].clone();
                    let sw = Stopwatch::start();
                    // Retry on QueueFull: a closed loop should measure
                    // service time, not shed its own load.
                    loop {
                        match router.submit_wait(img.clone()) {
                            Ok(_) => break,
                            Err(e) => {
                                assert_eq!(
                                    e,
                                    bitkernel::coordinator::RequestError::Rejected(
                                        bitkernel::coordinator::SubmitError::QueueFull,
                                    ),
                                    "{e}"
                                );
                                std::thread::yield_now();
                            }
                        }
                    }
                    lat.push(sw.elapsed_ms());
                }
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    (sw.elapsed_secs(), lat)
}

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

/// One measured grid point of the replica sweep.
struct SweepRow {
    replicas: usize,
    max_batch: usize,
    max_delay_ms: u64,
    requests: usize,
    clients: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
}

impl SweepRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("max_delay_ms", Json::Num(self.max_delay_ms as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("req_per_s", Json::Num(self.req_per_s)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("mean_batch", Json::Num(self.mean_batch)),
        ])
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg("--json");
    let host = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // --- 1. policy sweep with the mock backend (pure coordinator cost) --------
    let mut table = Table::new(
        "Batching policy sweep (mock backend, 2ms/batch, 16 clients, 1 replica)",
        &["max_batch", "max_delay", "req/s", "p50 ms", "p99 ms",
          "mean batch"],
    );
    let mock_requests = if quick { 64 } else { 256 };
    let synth_image = vec![0.1f32; 3 * 32 * 32];
    for (mb, delay_ms) in
        [(1, 0u64), (4, 1), (8, 2), (8, 10), (16, 2), (32, 5)]
    {
        let router = Router::start(
            move |_| Ok(Box::new(MockBackend::new(mb, 2)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 1024,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: mb,
                    max_delay: Duration::from_millis(delay_ms),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let (wall, lat) = drive(
            &router,
            std::slice::from_ref(&synth_image),
            mock_requests,
            16,
        );
        let snap = router.metrics().snapshot();
        table.row(&[
            format!("{mb}"),
            format!("{delay_ms}ms"),
            format!("{:.0}", mock_requests as f64 / wall),
            format!("{:.2}", percentile(&lat, 0.5)),
            format!("{:.2}", percentile(&lat, 0.99)),
            format!("{:.2}", snap.mean_batch_size),
        ]);
    }
    table.print();

    // --- 2. replica scaling sweep (synthetic BNN, one shared plan) ------------
    // Widths big enough that a batch costs real compute (so replica
    // scaling is visible over coordinator overhead) but small enough
    // for a quick sweep.
    let engine = synthetic_engine([32, 32, 64, 64, 64, 64, 128, 128, 10], 99);
    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> =
        (0..32).map(|_| rng.normal_vec(3 * 32 * 32)).collect();
    let (requests, clients) = if quick { (64, 8) } else { (512, 32) };
    let replica_grid: Vec<usize> = {
        let mut v = if quick { vec![1, host.min(4)] } else { vec![1, 2, 4] };
        v.dedup();
        v
    };
    let policy_grid: &[(usize, u64)] =
        if quick { &[(8, 2)] } else { &[(1, 0), (8, 2), (16, 5)] };

    let mut table = Table::new(
        &format!(
            "Replica scaling sweep (synthetic BNN, one shared plan, \
             {requests} req, {clients} clients, {host}-core host)"
        ),
        &["replicas", "max_batch", "max_delay", "req/s", "p50 ms",
          "p99 ms", "mean batch"],
    );
    let mut rows: Vec<SweepRow> = Vec::new();
    for &(mb, delay_ms) in policy_grid {
        // One compile per policy point, shared across every replica
        // count — exactly the serving deployment's shape.
        let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), mb).unwrap();
        for &replicas in &replica_grid {
            let plan = plan.clone();
            let router = Router::start(
                move |_| {
                    Ok(Box::new(NativeBackend::from_plan(&plan))
                        as Box<dyn Backend>)
                },
                RouterConfig {
                    queue_cap: 1024,
                    replicas,
                    batcher: BatcherConfig {
                        max_batch: mb,
                        max_delay: Duration::from_millis(delay_ms),
                    },
                    ..RouterConfig::default()
                },
            )
            .unwrap();
            let (wall, lat) = drive(&router, &images, requests, clients);
            let snap = router.metrics().snapshot();
            router.shutdown();
            let row = SweepRow {
                replicas,
                max_batch: mb,
                max_delay_ms: delay_ms,
                requests,
                clients,
                req_per_s: requests as f64 / wall,
                p50_ms: percentile(&lat, 0.5),
                p99_ms: percentile(&lat, 0.99),
                mean_batch: snap.mean_batch_size,
            };
            table.row(&[
                format!("{replicas}"),
                format!("{mb}"),
                format!("{delay_ms}ms"),
                format!("{:.0}", row.req_per_s),
                format!("{:.2}", row.p50_ms),
                format!("{:.2}", row.p99_ms),
                format!("{:.2}", row.mean_batch),
            ]);
            rows.push(row);
        }
    }
    table.print();

    // Acceptance check (informational; perf varies per host): at equal
    // policy, the widest pool should scale throughput.
    for &(mb, delay_ms) in policy_grid {
        let at = |r: usize| {
            rows.iter().find(|x| {
                x.replicas == r
                    && x.max_batch == mb
                    && x.max_delay_ms == delay_ms
            })
        };
        let (Some(one), Some(widest)) = (
            at(1),
            replica_grid.iter().rev().find_map(|&r| at(r).filter(|_| r > 1)),
        ) else {
            continue;
        };
        let speedup = widest.req_per_s / one.req_per_s;
        println!(
            "acceptance: {}x replicas vs 1 at max_batch={mb}: {speedup:.2}x \
             req/s ({})",
            widest.replicas,
            if speedup >= 2.0 || host < 4 {
                "PASS >= 2x (or host < 4 cores)"
            } else {
                "below 2x"
            }
        );
    }

    if let Some(path) = json_path {
        let json =
            Json::Arr(rows.iter().map(SweepRow::to_json).collect());
        std::fs::write(&path, json.to_string()).expect("write json");
        println!("wrote {path}");
    }

    // --- 3. trained model (needs artifacts) ------------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping trained-model batching bench: no artifacts)");
        return;
    }
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let real_images: Vec<Vec<f32>> = (0..32.min(ds.count))
        .map(|i| ds.normalized(i, i + 1).into_data())
        .collect();
    let weights = dir.join("weights_small.bkw");
    let engine = BnnEngine::load(&weights).unwrap();
    let mut table = Table::new(
        "Batching with the trained BNN (native xnor, 64 req, 8 clients)",
        &["replicas", "max_batch", "req/s", "mean ms", "p99 ms",
          "mean batch"],
    );
    let mut trained_grid = vec![(1usize, 1usize), (1, 8), (host.min(4), 8)];
    trained_grid.dedup();
    for (replicas, mb) in trained_grid {
        let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), mb).unwrap();
        let router = Router::start(
            move |_| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 256,
                replicas,
                batcher: BatcherConfig {
                    max_batch: mb,
                    max_delay: Duration::from_millis(3),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let (wall, lat) = drive(&router, &real_images, 64, 8);
        let snap = router.metrics().snapshot();
        table.row(&[
            format!("{replicas}"),
            format!("{mb}"),
            format!("{:.1}", 64.0 / wall),
            format!("{:.1}", mean(&lat)),
            format!("{:.1}", percentile(&lat, 0.99)),
            format!("{:.2}", snap.mean_batch_size),
        ]);
    }
    table.print();
}
