//! The serving service: model-name -> Router dispatch + HTTP plumbing.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Router, SubmitError};
use crate::data::normalize_batch;
use crate::utils::json::Json;
use crate::{log_error, log_info};

use super::http::{HttpRequest, HttpResponse};

/// ShapeSet-10 class labels, indexed by class id.
pub const CLASS_NAMES: [&str; 10] = [
    "circle", "square", "triangle", "cross", "ring",
    "h-stripe", "v-stripe", "checker", "dot-grid", "diag-gradient",
];

const IMAGE_BYTES: usize = 32 * 32 * 3;

/// A named collection of routers behind one HTTP endpoint.
pub struct Service {
    routers: BTreeMap<String, Router>,
    default_model: String,
}

impl Service {
    /// Build a service over named routers; `default_model` answers
    /// `/classify` requests that carry no `?model=` parameter.
    pub fn new(routers: BTreeMap<String, Router>, default_model: &str) -> Self {
        assert!(routers.contains_key(default_model), "unknown default model");
        Self { routers, default_model: default_model.to_string() }
    }

    /// Names of every served model.
    pub fn models(&self) -> Vec<String> {
        self.routers.keys().cloned().collect()
    }

    /// The router serving `name`, if any.
    pub fn router(&self, name: &str) -> Option<&Router> {
        self.routers.get(name)
    }

    /// Dispatch one parsed request.
    pub fn handle(&self, req: &HttpRequest) -> HttpResponse {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => HttpResponse::text(200, "ok\n"),
            ("GET", "/models") => {
                let names: Vec<Json> = self
                    .routers
                    .iter()
                    .map(|(name, r)| {
                        Json::obj(vec![
                            ("name", Json::Str(name.clone())),
                            ("backend",
                             Json::Str(r.backend_name().to_string())),
                        ])
                    })
                    .collect();
                HttpResponse::json(200, Json::Arr(names).to_string())
            }
            ("GET", "/metrics") => {
                let mut out = String::new();
                for (name, r) in &self.routers {
                    // Label merging happens in the renderer so
                    // per-replica lines (which already carry a
                    // `replica` label) stay well-formed.
                    out.push_str(&r.metrics().render_prometheus_labeled(
                        &format!("model=\"{name}\""),
                    ));
                }
                HttpResponse::text(200, out)
            }
            ("POST", "/classify") => self.classify(req),
            ("GET", _) | ("POST", _) => {
                HttpResponse::text(404, "not found\n")
            }
            _ => HttpResponse::text(405, "method not allowed\n"),
        }
    }

    fn classify(&self, req: &HttpRequest) -> HttpResponse {
        let model = req
            .query
            .get("model")
            .cloned()
            .unwrap_or_else(|| self.default_model.clone());
        let Some(router) = self.routers.get(&model) else {
            return HttpResponse::json(
                404,
                format!("{{\"error\":\"unknown model '{model}'\"}}"),
            );
        };
        let pixels = match decode_pixels(req) {
            Ok(p) => p,
            Err(e) => {
                return HttpResponse::json(
                    400,
                    format!("{{\"error\":\"{e}\"}}"),
                )
            }
        };
        let image = normalize_batch(&pixels, 1, 32, 32, 3);
        match router.submit_wait(image.into_data()) {
            Ok(reply) => {
                let body = Json::obj(vec![
                    ("class", Json::Num(reply.class as f64)),
                    ("label",
                     Json::Str(CLASS_NAMES[reply.class].to_string())),
                    ("latency_us", Json::Num(reply.total_us as f64)),
                    ("queue_us", Json::Num(reply.queue_us as f64)),
                    (
                        "logits",
                        Json::Arr(
                            reply
                                .logits
                                .iter()
                                .map(|&v| Json::Num(v as f64))
                                .collect(),
                        ),
                    ),
                ]);
                HttpResponse::json(200, body.to_string())
            }
            Err(SubmitError::QueueFull) => HttpResponse::json(
                429,
                "{\"error\":\"queue full\"}".into(),
            ),
            Err(SubmitError::Shutdown) => HttpResponse::json(
                503,
                "{\"error\":\"shutting down\"}".into(),
            ),
        }
    }
}

/// Accept raw 3072-byte bodies or JSON {"pixels": [...]}.
fn decode_pixels(req: &HttpRequest) -> Result<Vec<u8>> {
    let ct = req
        .headers
        .get("content-type")
        .map(String::as_str)
        .unwrap_or("application/octet-stream");
    if ct.starts_with("application/json") {
        let text = std::str::from_utf8(&req.body).context("body utf-8")?;
        let v = Json::parse(text).context("body json")?;
        let arr = v
            .get("pixels")
            .and_then(|p| p.as_arr())
            .context("missing 'pixels' array")?;
        anyhow::ensure!(arr.len() == IMAGE_BYTES,
                        "expected {IMAGE_BYTES} pixels, got {}", arr.len());
        arr.iter()
            .map(|x| {
                let n = x.as_f64().context("pixel not a number")?;
                anyhow::ensure!((0.0..=255.0).contains(&n), "pixel range");
                Ok(n as u8)
            })
            .collect()
    } else {
        anyhow::ensure!(req.body.len() == IMAGE_BYTES,
                        "expected {IMAGE_BYTES} body bytes, got {}",
                        req.body.len());
        Ok(req.body.clone())
    }
}

/// Serving options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Connection-handler threads.
    pub threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { addr: "127.0.0.1:8080".into(), threads: 4 }
    }
}

/// Run the accept loop until `stop` flips true.  Returns the bound
/// address (useful with port 0 in tests).
pub fn serve(
    service: Arc<Service>,
    opts: &ServeOptions,
    stop: Arc<AtomicBool>,
    ready_tx: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    log_info!("serving on http://{addr} (models: {:?})", service.models());
    if let Some(tx) = ready_tx {
        let _ = tx.send(addr);
    }
    let pool = crate::utils::threadpool::ThreadPool::new(opts.threads);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let svc = Arc::clone(&service);
                pool.execute(move || {
                    if let Err(e) = handle_connection(stream, &svc) {
                        crate::log_debug!("connection error: {e:#}");
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                log_error!("accept: {e}");
                break;
            }
        }
    }
    Ok(())
}

fn handle_connection(stream: TcpStream, service: &Service) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let Some(req) = HttpRequest::read(&mut reader)? else {
            return Ok(()); // clean close
        };
        let keep_alive = req.wants_keep_alive();
        let resp = service.handle(&req);
        resp.write(&mut writer, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{MockBackend, Router, RouterConfig};
    use crate::coordinator::backend as bitkernel_backend;
    use std::collections::BTreeMap;

    fn mock_service() -> Service {
        let mut routers = BTreeMap::new();
        routers.insert(
            "mock".to_string(),
            Router::start(
                |_| Ok(Box::new(MockBackend::new(4, 0))
                       as Box<dyn bitkernel_backend::Backend>),
                RouterConfig { replicas: 2, ..RouterConfig::default() },
            )
            .unwrap(),
        );
        Service::new(routers, "mock")
    }

    fn get(path: &str) -> HttpRequest {
        HttpRequest {
            method: "GET".into(),
            path: path.into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: vec![],
        }
    }

    #[test]
    fn healthz_and_models() {
        let svc = mock_service();
        assert_eq!(svc.handle(&get("/healthz")).status, 200);
        let resp = svc.handle(&get("/models"));
        assert_eq!(resp.status, 200);
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.contains("mock"));
    }

    #[test]
    fn metrics_labelled_per_model() {
        let svc = mock_service();
        let resp = svc.handle(&get("/metrics"));
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.contains("bitkernel_requests_submitted{model=\"mock\"}"),
                "{body}");
        // Per-replica series carry both labels, well-formed.
        assert!(body.contains(
            "bitkernel_replica_requests{model=\"mock\",replica=\"0\"}"
        ), "{body}");
        assert!(!body.contains("}{"), "{body}");
    }

    #[test]
    fn classify_raw_body() {
        let svc = mock_service();
        let req = HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: vec![200u8; IMAGE_BYTES],
        };
        let resp = svc.handle(&req);
        assert_eq!(resp.status, 200, "{}",
                   String::from_utf8_lossy(&resp.body));
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.contains("\"class\""));
        assert!(body.contains("\"label\""));
    }

    #[test]
    fn classify_json_body() {
        let svc = mock_service();
        let pixels: Vec<String> =
            (0..IMAGE_BYTES).map(|i| (i % 256).to_string()).collect();
        let mut headers = BTreeMap::new();
        headers.insert("content-type".into(), "application/json".into());
        let req = HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query: BTreeMap::new(),
            headers,
            body: format!("{{\"pixels\":[{}]}}", pixels.join(","))
                .into_bytes(),
        };
        assert_eq!(svc.handle(&req).status, 200);
    }

    #[test]
    fn classify_rejects_bad_sizes_and_unknown_model() {
        let svc = mock_service();
        let mut req = HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: vec![0u8; 10],
        };
        assert_eq!(svc.handle(&req).status, 400);
        req.body = vec![0u8; IMAGE_BYTES];
        req.query.insert("model".into(), "nope".into());
        assert_eq!(svc.handle(&req).status, 404);
    }

    #[test]
    fn unknown_path_404() {
        let svc = mock_service();
        assert_eq!(svc.handle(&get("/nope")).status, 404);
    }
}
