//! The serving service: registry-backed model dispatch + HTTP plumbing.
//!
//! Fully shape-generic: every route derives its request/reply schema
//! from the target model's captured shape contract
//! ([`Router::input_shape`] / [`Router::classes`] /
//! [`Router::labels`]), so one endpoint serves heterogeneous models —
//! each model's classify body is `C*H*W` bytes (or a same-length JSON
//! pixel array), and replies carry the model's own label table when
//! the weight file embeds one (numeric labels otherwise).  No image
//! geometry is hardwired anywhere in this module.
//!
//! The model set is **dynamic**: it lives in a
//! [`ModelRegistry`](super::registry::ModelRegistry) rather than a
//! frozen map, and (when the service is started with the admin API
//! enabled) can be edited over HTTP while `/classify` traffic is in
//! flight:
//!
//! ```text
//!     POST   /models             mount  {"name","path","lazy"?}
//!     PUT    /models/{name}      reload from the mounted path
//!     DELETE /models/{name}      unmount (drain, then retire)
//!     GET    /models/{name}      lifecycle state + shape contract
//!     GET    /models             all of the above, for every model
//! ```
//!
//! Mutating verbs answer `202 Accepted` immediately (the build runs
//! off-thread); append `?wait=1` for synchronous semantics (`201`/`200`
//! once ready, `500` carrying the build error on failure).  Without
//! `--admin` the mutating verbs are `403` and the registry is
//! effectively frozen — the pre-PR-6 behavior.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{
    InferReply, Metrics, ReplyError, RequestError, Router, SubmitError,
    SubmitOptions,
};
use crate::data::normalize_batch;
use crate::utils::json::Json;
use crate::{log_error, log_info};

use super::http::{HttpRequest, HttpResponse};
use super::registry::{
    ModelRegistry, ModelState, ModelStatus, RegistryConfig, RegistryError,
};

/// How long `?wait=1` admin calls block for a build to settle.
const ADMIN_WAIT: Duration = Duration::from_secs(60);

/// Server-side cap on a client-requested `?timeout_ms=`: whatever the
/// client asks for, no request occupies the pipeline longer than this.
const MAX_TIMEOUT_MS: u64 = 60_000;

/// Front-end connection counters, shared by whichever front end
/// (blocking pool or epoll event loop) the process runs, and rendered
/// on `/metrics` next to the per-model series.
#[derive(Debug, Default)]
pub struct HttpMetrics {
    /// Currently open connections (gauge).
    pub connections: AtomicU64,
    /// Connections accepted since start.
    pub accepts: AtomicU64,
    /// Connections shed at the door over `max_connections`.
    pub rejected_over_limit: AtomicU64,
    /// Requests served on an already-used keep-alive connection
    /// (the second request onward counts as one reuse each).
    pub keepalive_reuses: AtomicU64,
}

impl HttpMetrics {
    /// Prometheus-style exposition of the front-end series.
    pub fn render(&self) -> String {
        let mut out = Metrics::render_series(
            "bitkernel_http_connections",
            "",
            self.connections.load(Ordering::Relaxed),
        );
        out.push_str(&Metrics::render_series(
            "bitkernel_http_accepts_total",
            "",
            self.accepts.load(Ordering::Relaxed),
        ));
        out.push_str(&Metrics::render_series(
            "bitkernel_http_rejected_over_limit_total",
            "",
            self.rejected_over_limit.load(Ordering::Relaxed),
        ));
        out.push_str(&Metrics::render_series(
            "bitkernel_http_keepalive_reuses_total",
            "",
            self.keepalive_reuses.load(Ordering::Relaxed),
        ));
        out
    }
}

/// The HTTP front end over a dynamic [`ModelRegistry`].  Dispatch is
/// by model name; each request is decoded against its target's
/// contract.
pub struct Service {
    registry: Arc<ModelRegistry>,
    default_model: Option<String>,
    admin: bool,
    http_metrics: Arc<HttpMetrics>,
}

impl Service {
    /// Build a service over pre-built named routers; `default_model`
    /// answers `/classify` requests that carry no `?model=` parameter.
    /// The model set is frozen (admin API disabled) — the bridge for
    /// callers predating the registry.
    pub fn new(routers: BTreeMap<String, Router>, default_model: &str) -> Self {
        assert!(
            routers.contains_key(default_model),
            "unknown default model"
        );
        let registry = ModelRegistry::new(RegistryConfig::default());
        for (name, router) in routers {
            registry
                .insert_router(&name, router)
                .expect("fresh registry cannot hold duplicates");
        }
        Self {
            registry,
            default_model: Some(default_model.to_string()),
            admin: false,
            http_metrics: Arc::new(HttpMetrics::default()),
        }
    }

    /// Build a service over a live registry.  `default_model` (if any)
    /// answers `/classify` requests with no `?model=`; `admin` enables
    /// the mutating admin verbs.
    pub fn with_registry(
        registry: Arc<ModelRegistry>,
        default_model: Option<String>,
        admin: bool,
    ) -> Self {
        Self {
            registry,
            default_model,
            admin,
            http_metrics: Arc::new(HttpMetrics::default()),
        }
    }

    /// The registry behind this service.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Front-end connection counters (shared by every front end that
    /// serves this service).
    pub fn http_metrics(&self) -> &Arc<HttpMetrics> {
        &self.http_metrics
    }

    /// Names of every mounted model.
    pub fn models(&self) -> Vec<String> {
        self.registry.list().into_iter().map(|s| s.name).collect()
    }

    /// Dispatch one parsed request.  Takes the request by value: the
    /// classify path normalizes straight out of the body buffer, so
    /// large-input models never pay an intermediate raw-byte clone.
    pub fn handle(&self, req: HttpRequest) -> HttpResponse {
        // classify consumes the request, so it is routed before the
        // borrowing match below.
        if req.method == "POST" && req.path == "/classify" {
            return self.classify(req);
        }
        if req.method == "POST" && req.path == "/models" {
            return self.admin_mount(&req);
        }
        if let Some(name) = req.path.strip_prefix("/models/") {
            return self.model_route(&req, name);
        }
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => HttpResponse::text(200, "ok\n"),
            ("GET", "/models") => {
                let entries: Vec<Json> = self
                    .registry
                    .list()
                    .iter()
                    .map(status_descriptor)
                    .collect();
                HttpResponse::json(200, Json::Arr(entries).to_string())
            }
            ("GET", "/metrics") => {
                let mut body = self.registry.render_prometheus();
                body.push_str(&self.http_metrics.render());
                body.push_str(&crate::model::calib::render_metrics());
                HttpResponse::text(200, body)
            }
            ("GET", _) | ("POST", _) => {
                HttpResponse::text(404, "not found\n")
            }
            _ => HttpResponse::text(405, "method not allowed\n"),
        }
    }

    /// `POST /models`: mount a model from a JSON body
    /// `{"name": ..., "path": ..., "lazy": bool?}`.
    fn admin_mount(&self, req: &HttpRequest) -> HttpResponse {
        if let Some(denied) = self.admin_gate() {
            return denied;
        }
        let parsed = (|| -> Result<(String, String, bool)> {
            let text =
                std::str::from_utf8(&req.body).context("body utf-8")?;
            let v = Json::parse(text).context("body json")?;
            let name = v
                .get("name")
                .and_then(Json::as_str)
                .context("missing 'name'")?
                .to_string();
            let path = v
                .get("path")
                .and_then(Json::as_str)
                .context("missing 'path'")?
                .to_string();
            let lazy = v
                .get("lazy")
                .and_then(Json::as_bool)
                .unwrap_or(false);
            Ok((name, path, lazy))
        })();
        let (name, path, lazy) = match parsed {
            Ok(p) => p,
            Err(e) => return err_json(400, &format!("{e:#}")),
        };
        let entry = match self.registry.mount(&name, path, lazy) {
            Ok(e) => e,
            Err(e) => return registry_err(&e),
        };
        if !wants_wait(req) {
            return HttpResponse::json(
                202,
                status_descriptor(&entry.status()).to_string(),
            );
        }
        let st = entry.wait_settled(ADMIN_WAIT);
        match st.state {
            ModelState::Failed => err_json(
                500,
                st.error.as_deref().unwrap_or("build failed"),
            ),
            ModelState::Loading => HttpResponse::json(
                202,
                status_descriptor(&st).to_string(),
            ),
            _ => HttpResponse::json(
                201,
                status_descriptor(&st).to_string(),
            ),
        }
    }

    /// `GET | PUT | DELETE /models/{name}`.
    fn model_route(&self, req: &HttpRequest, name: &str) -> HttpResponse {
        match req.method.as_str() {
            "GET" => match self.registry.status(name) {
                Ok(st) => HttpResponse::json(
                    200,
                    status_descriptor(&st).to_string(),
                ),
                Err(e) => registry_err(&e),
            },
            "PUT" => {
                if let Some(denied) = self.admin_gate() {
                    return denied;
                }
                let entry = match self.registry.reload(name) {
                    Ok(e) => e,
                    Err(e) => return registry_err(&e),
                };
                if !wants_wait(req) {
                    return HttpResponse::json(
                        202,
                        status_descriptor(&entry.status()).to_string(),
                    );
                }
                let st = entry.wait_settled(ADMIN_WAIT);
                // A reload that failed rolls back to `ready` on the old
                // generation with the error recorded — surface it.
                if let Some(error) = &st.error {
                    return err_json(500, error);
                }
                if st.state == ModelState::Loading {
                    return HttpResponse::json(
                        202,
                        status_descriptor(&st).to_string(),
                    );
                }
                HttpResponse::json(200, status_descriptor(&st).to_string())
            }
            "DELETE" => {
                if let Some(denied) = self.admin_gate() {
                    return denied;
                }
                match self.registry.unmount(name) {
                    Ok(()) => HttpResponse::json(
                        200,
                        Json::obj(vec![(
                            "unmounted",
                            Json::Str(name.to_string()),
                        )])
                        .to_string(),
                    ),
                    Err(e) => registry_err(&e),
                }
            }
            _ => HttpResponse::text(405, "method not allowed\n"),
        }
    }

    /// `None` when admin verbs are allowed, the 403 otherwise.
    fn admin_gate(&self) -> Option<HttpResponse> {
        if self.admin {
            None
        } else {
            Some(err_json(
                403,
                "admin API disabled (start serve with --admin)",
            ))
        }
    }

    fn classify(&self, req: HttpRequest) -> HttpResponse {
        let content_type =
            req.headers.get("content-type").map(String::as_str);
        let prepared =
            self.prepare_classify(&req.query, content_type, &req.body);
        let p = match prepared {
            Ok(p) => p,
            Err(resp) => return resp,
        };
        let PreparedClassify { model, router, generation, opts, image } = p;
        let result = router.submit_wait_deadline(image, opts);
        classify_response(&model, generation, &router, result)
    }

    /// Validate and dispatch one classify request WITHOUT blocking on
    /// the reply — the event-loop front end's submission path.
    /// `respond` runs exactly once with the final response: inline on
    /// the calling thread for validation/admission failures, from a
    /// replica worker thread once inference resolves otherwise (so it
    /// must not block and must not panic).
    pub fn classify_async(
        &self,
        query: &BTreeMap<String, String>,
        content_type: Option<&str>,
        body: &[u8],
        respond: impl FnOnce(HttpResponse) + Send + 'static,
    ) {
        let p = match self.prepare_classify(query, content_type, body) {
            Ok(p) => p,
            Err(resp) => {
                respond(resp);
                return;
            }
        };
        let PreparedClassify { model, router, generation, opts, image } = p;
        // One-shot slot: `submit_callback` may fail synchronously
        // AFTER the closure has taken ownership of `respond` (the
        // queue-full path drops the request, closure included), so
        // both resolution paths draw from the same Option.
        let slot = Arc::new(std::sync::Mutex::new(Some(respond)));
        let cb_slot = Arc::clone(&slot);
        let cb_router = Arc::clone(&router);
        let cb_model = model.clone();
        let submitted = router.submit_callback(image, opts, move |result| {
            if let Some(f) = cb_slot.lock().unwrap().take() {
                f(classify_response(
                    &cb_model,
                    generation,
                    &cb_router,
                    result.map_err(RequestError::Failed),
                ));
            }
        });
        if let Err(e) = submitted {
            if let Some(f) = slot.lock().unwrap().take() {
                f(classify_response(
                    &model,
                    generation,
                    &router,
                    Err(RequestError::Rejected(e)),
                ));
            }
        }
    }

    /// Shared classify admission: resolve the model, pin its
    /// `(router, generation)`, parse options, and decode the body
    /// against the model's contract.  `Err` is the ready-to-send
    /// rejection response.
    fn prepare_classify(
        &self,
        query: &BTreeMap<String, String>,
        content_type: Option<&str>,
        body: &[u8],
    ) -> Result<PreparedClassify, HttpResponse> {
        let model = match query.get("model").cloned() {
            Some(m) => m,
            None => match &self.default_model {
                Some(m) => m.clone(),
                None => {
                    return Err(err_json(
                        404,
                        "no default model (pass ?model=<name>)",
                    ))
                }
            },
        };
        // Resolving first pins this request's (router, generation):
        // a concurrent reload swaps the registry's handle but cannot
        // invalidate ours — the retired router drains only after the
        // last in-flight clone drops.
        let (router, generation) = match self.registry.router_for(&model) {
            Ok(r) => r,
            Err(e) => return Err(registry_err(&e)),
        };
        // Circuit open: every replica of this model is mid-respawn.
        // Shed at the door with a retry hint instead of queueing into
        // a pool that cannot currently drain.
        if router.circuit_open() {
            return Err(err_json(503, "all replicas restarting")
                .with_header("Retry-After", "1"));
        }
        let opts = match query.get("timeout_ms") {
            Some(v) => match v.parse::<u64>() {
                Ok(ms) => SubmitOptions::with_timeout(
                    Duration::from_millis(ms.min(MAX_TIMEOUT_MS)),
                ),
                Err(_) => {
                    return Err(err_json(
                        400,
                        "bad timeout_ms (want integer milliseconds)",
                    ))
                }
            },
            None => SubmitOptions::default(),
        };
        let (c, h, w) = router.input_shape();
        let image = match decode_image(body, content_type, c, h, w) {
            Ok(i) => i,
            Err(e) => return Err(err_json(400, &format!("{e:#}"))),
        };
        Ok(PreparedClassify { model, router, generation, opts, image })
    }
}

/// One classify request past admission: everything dispatch needs.
struct PreparedClassify {
    model: String,
    router: Arc<Router>,
    generation: u64,
    opts: SubmitOptions,
    image: Vec<f32>,
}

/// Map one dispatch outcome to its classify HTTP response — shared by
/// the blocking and event-loop paths so status mapping cannot drift
/// between front ends.
fn classify_response(
    model: &str,
    generation: u64,
    router: &Router,
    result: Result<InferReply, RequestError>,
) -> HttpResponse {
    match result {
        Ok(reply) => {
            // Label-less models answer with numeric labels.
            let label = router.label_for(reply.class);
            let body = Json::obj(vec![
                ("model", Json::Str(model.to_string())),
                ("generation", Json::Num(generation as f64)),
                ("class", Json::Num(reply.class as f64)),
                ("label", Json::Str(label)),
                ("latency_us", Json::Num(reply.total_us as f64)),
                ("queue_us", Json::Num(reply.queue_us as f64)),
                (
                    "logits",
                    Json::Arr(
                        reply
                            .logits
                            .iter()
                            .map(|&v| Json::Num(v as f64))
                            .collect(),
                    ),
                ),
            ]);
            HttpResponse::json(200, body.to_string())
        }
        Err(RequestError::Rejected(SubmitError::QueueFull)) => {
            err_json(429, "queue full")
        }
        // Unreachable (the image was sized from the router's own
        // contract), but kept total: a shape error is the client's
        // fault, never a 500.
        Err(RequestError::Rejected(e @ SubmitError::WrongShape {
            ..
        })) => err_json(400, &e.to_string()),
        Err(RequestError::Rejected(SubmitError::Shutdown))
        | Err(RequestError::Failed(ReplyError::Shutdown)) => {
            err_json(503, "shutting down")
        }
        Err(RequestError::Failed(ReplyError::DeadlineExceeded)) => {
            err_json(504, "deadline exceeded")
        }
        // Replica panic / backend failure: the request is lost but
        // typed — the supervisor is already respawning the replica.
        Err(RequestError::Failed(e)) => err_json(500, &e.to_string()),
    }
}

/// `{"error": msg}` with proper JSON escaping.
fn err_json(status: u16, msg: &str) -> HttpResponse {
    HttpResponse::json(
        status,
        Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string(),
    )
}

/// Map a typed registry failure to its HTTP status.
fn registry_err(e: &RegistryError) -> HttpResponse {
    let status = match e {
        RegistryError::NotFound(_) => 404,
        RegistryError::BadName(_) => 400,
        RegistryError::AlreadyMounted(_)
        | RegistryError::NotReloadable(_)
        | RegistryError::ReloadInProgress(_) => 409,
        RegistryError::Failed { .. } | RegistryError::LoadTimeout(_) => 503,
    };
    err_json(status, &e.to_string())
}

/// Whether an admin call asked for synchronous (`?wait=1`) semantics.
fn wants_wait(req: &HttpRequest) -> bool {
    matches!(
        req.query.get("wait").map(String::as_str),
        Some("1") | Some("true")
    )
}

/// One `/models` entry: lifecycle state plus (once known) the model's
/// full shape contract, so clients can size request bodies without
/// out-of-band knowledge.
fn status_descriptor(st: &ModelStatus) -> Json {
    let mut fields = vec![
        ("name", Json::Str(st.name.clone())),
        ("state", Json::Str(st.state.as_str().to_string())),
        ("generation", Json::Num(st.generation as f64)),
        ("resident", Json::Bool(st.resident)),
        ("reloadable", Json::Bool(st.reloadable)),
        ("circuit_open", Json::Bool(st.circuit_open)),
        (
            "error",
            match &st.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ),
    ];
    if let Some(contract) = &st.contract {
        let (c, h, w) = contract.input_shape;
        fields.push(("backend", Json::Str(contract.backend.clone())));
        fields.push((
            "input_shape",
            Json::Arr(
                [c, h, w].iter().map(|&d| Json::Num(d as f64)).collect(),
            ),
        ));
        fields.push((
            "image_bytes",
            Json::Num(contract.image_bytes() as f64),
        ));
        fields.push(("classes", Json::Num(contract.classes as f64)));
        fields.push((
            "scheme",
            match &contract.scheme {
                Some(s) => Json::Str(s.clone()),
                None => Json::Null,
            },
        ));
        fields.push((
            "labels",
            match &contract.labels {
                Some(l) => Json::Arr(
                    l.iter().map(|s| Json::Str(s.clone())).collect(),
                ),
                None => Json::Null,
            },
        ));
    }
    Json::obj(fields)
}

/// Decode one classify body into a normalized CHW image for a
/// `(c, h, w)` model: either exactly `c*h*w` raw HWC uint8 bytes, or
/// JSON `{"pixels": [...]}` with `c*h*w` numbers in [0, 255]
/// (fractional values allowed).  Both normalize as `x / 127.5 - 1`,
/// matching the training pipeline.  Borrows the body so the event
/// loop normalizes straight out of its connection buffer — the only
/// copy is the normalized f32 image itself.
fn decode_image(
    body: &[u8],
    content_type: Option<&str>,
    c: usize,
    h: usize,
    w: usize,
) -> Result<Vec<f32>> {
    let elems = c * h * w;
    let ct = content_type.unwrap_or("application/octet-stream");
    if ct.starts_with("application/json") {
        let text = std::str::from_utf8(body).context("body utf-8")?;
        let v = Json::parse(text).context("body json")?;
        let arr = v
            .get("pixels")
            .and_then(|p| p.as_arr())
            .context("missing 'pixels' array")?;
        anyhow::ensure!(arr.len() == elems,
                        "expected {elems} pixels for this model's \
                         {c}x{h}x{w} input, got {}", arr.len());
        // HWC pixel order (like the raw encoding) -> normalized CHW.
        let mut out = vec![0.0f32; elems];
        for (i, x) in arr.iter().enumerate() {
            let n = x.as_f64().context("pixel not a number")?;
            anyhow::ensure!((0.0..=255.0).contains(&n), "pixel range");
            let (y, xx, ch) = (i / (w * c), (i / c) % w, i % c);
            out[(ch * h + y) * w + xx] = n as f32 / 127.5 - 1.0;
        }
        Ok(out)
    } else {
        anyhow::ensure!(body.len() == elems,
                        "expected {elems} body bytes for this model's \
                         {c}x{h}x{w} input, got {}", body.len());
        Ok(normalize_batch(body, 1, h, w, c).into_data())
    }
}

/// Serving options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Connection-handler threads (blocking front end) — the
    /// event-loop front end sizes its auxiliary pool from this too.
    pub threads: usize,
    /// Open-connection cap: accepts past this are answered `503` with
    /// a `Retry-After` hint and closed immediately, keeping the
    /// handler pool responsive for the connections already admitted.
    pub max_connections: usize,
    /// Close a connection that has sat idle (no bytes of a new
    /// request) longer than this.  `serve --idle-timeout-ms`; shared
    /// by both front ends.
    pub idle_timeout: Duration,
    /// Serve with the epoll event-loop front end instead of the
    /// blocking thread-per-connection pool (`serve --event-loop`).
    /// Linux-only; elsewhere it logs a warning and falls back.
    pub event_loop: bool,
    /// Reactor threads for the event-loop front end
    /// (`serve --io-threads`).
    pub io_threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8080".into(),
            threads: 4,
            max_connections: 256,
            idle_timeout: Duration::from_secs(30),
            event_loop: false,
            io_threads: 1,
        }
    }
}

/// RAII decrement of the serve loop's open-connection count (and the
/// exported gauge) — runs on normal return AND on unwind out of a
/// handler.
struct ConnGuard {
    active: Arc<AtomicUsize>,
    metrics: Arc<HttpMetrics>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
        self.metrics.connections.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Run the accept loop until `stop` flips true.  Dispatches to the
/// epoll event-loop front end when `opts.event_loop` is set; the
/// default is the blocking thread-per-connection pool below.
pub fn serve(
    service: Arc<Service>,
    opts: &ServeOptions,
    stop: Arc<AtomicBool>,
    ready_tx: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    if opts.event_loop {
        #[cfg(target_os = "linux")]
        return super::eventloop::serve_event_loop(
            service, opts, stop, ready_tx,
        );
        #[cfg(not(target_os = "linux"))]
        crate::log_warn!(
            "--event-loop needs epoll (linux); \
             falling back to the blocking front end"
        );
    }
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    log_info!("serving on http://{addr} (models: {:?})", service.models());
    if let Some(tx) = ready_tx {
        let _ = tx.send(addr);
    }
    let pool = crate::utils::threadpool::ThreadPool::new(opts.threads);
    let active = Arc::new(AtomicUsize::new(0));
    let http_m = Arc::clone(&service.http_metrics);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if active.load(Ordering::Relaxed) >= opts.max_connections {
                    // Shed at the door, without occupying a pool slot.
                    http_m
                        .rejected_over_limit
                        .fetch_add(1, Ordering::Relaxed);
                    let _ = HttpResponse::text(
                        503,
                        "server at connection capacity\n",
                    )
                    .with_header("Retry-After", "1")
                    .write(&mut stream, false);
                    continue;
                }
                active.fetch_add(1, Ordering::Relaxed);
                http_m.accepts.fetch_add(1, Ordering::Relaxed);
                http_m.connections.fetch_add(1, Ordering::Relaxed);
                let guard = ConnGuard {
                    active: Arc::clone(&active),
                    metrics: Arc::clone(&http_m),
                };
                let svc = Arc::clone(&service);
                let idle = opts.idle_timeout;
                pool.execute(move || {
                    let _guard = guard;
                    if let Err(e) = handle_connection(stream, &svc, idle) {
                        crate::log_debug!("connection error: {e:#}");
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                log_error!("accept: {e}");
                break;
            }
        }
    }
    Ok(())
}

fn handle_connection(
    stream: TcpStream,
    service: &Service,
    idle_timeout: Duration,
) -> Result<()> {
    stream.set_read_timeout(Some(idle_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut served: u64 = 0;
    loop {
        let req = match HttpRequest::read(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()), // clean close
            Err(e) => {
                // A parse/framing error leaves unknown bytes on the
                // stream, so the connection cannot be reused: answer a
                // best-effort 400 and close.
                let _ = err_json(400, &format!("{e:#}"))
                    .write(&mut writer, false);
                return Err(e);
            }
        };
        if served > 0 {
            service
                .http_metrics
                .keepalive_reuses
                .fetch_add(1, Ordering::Relaxed);
        }
        served += 1;
        let keep_alive = req.wants_keep_alive();
        let resp = service.handle(req);
        resp.write(&mut writer, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{MockBackend, Router, RouterConfig};
    use crate::coordinator::backend as bitkernel_backend;
    use std::collections::BTreeMap;

    /// Two heterogeneous models behind one service: "mock" speaks the
    /// legacy 3x32x32/10 shape and carries labels; "tiny" is a
    /// label-less 1x4x4/3 model.
    fn mock_service() -> Service {
        let mut routers = BTreeMap::new();
        routers.insert(
            "mock".to_string(),
            Router::start(
                |_| {
                    let mut b = MockBackend::new(4, 0);
                    b.labels = Some(
                        (0..10).map(|i| format!("shape-{i}")).collect(),
                    );
                    Ok(Box::new(b)
                        as Box<dyn bitkernel_backend::Backend>)
                },
                RouterConfig { replicas: 2, ..RouterConfig::default() },
            )
            .unwrap(),
        );
        routers.insert(
            "tiny".to_string(),
            Router::start(
                |_| Ok(Box::new(MockBackend::with_shape(4, 0, (1, 4, 4), 3))
                       as Box<dyn bitkernel_backend::Backend>),
                RouterConfig { replicas: 1, ..RouterConfig::default() },
            )
            .unwrap(),
        );
        Service::new(routers, "mock")
    }

    fn get(path: &str) -> HttpRequest {
        HttpRequest {
            method: "GET".into(),
            path: path.into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: vec![],
            version: "HTTP/1.1".into(),
        }
    }

    fn post(model: Option<&str>, body: Vec<u8>) -> HttpRequest {
        let mut query = BTreeMap::new();
        if let Some(m) = model {
            query.insert("model".into(), m.into());
        }
        HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query,
            headers: BTreeMap::new(),
            body,
            version: "HTTP/1.1".into(),
        }
    }

    #[test]
    fn healthz_and_models_report_shape_contracts() {
        let svc = mock_service();
        assert_eq!(svc.handle(get("/healthz")).status, 200);
        let resp = svc.handle(get("/models"));
        assert_eq!(resp.status, 200);
        let body = String::from_utf8(resp.body).unwrap();
        let v = Json::parse(&body).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let by_name = |n: &str| {
            arr.iter()
                .find(|m| m.get("name").unwrap().as_str() == Some(n))
                .unwrap()
        };
        let mock = by_name("mock");
        assert_eq!(mock.get("image_bytes").unwrap().as_usize(),
                   Some(3 * 32 * 32));
        assert_eq!(mock.get("classes").unwrap().as_usize(), Some(10));
        assert_eq!(
            mock.get("labels").unwrap().as_arr().map(<[Json]>::len),
            Some(10)
        );
        assert_eq!(mock.get("state").unwrap().as_str(), Some("ready"));
        assert_eq!(mock.get("resident").unwrap().as_bool(), Some(true));
        assert_eq!(mock.get("reloadable").unwrap().as_bool(), Some(false));
        assert_eq!(mock.get("circuit_open").unwrap().as_bool(),
                   Some(false));
        let tiny = by_name("tiny");
        assert_eq!(tiny.get("image_bytes").unwrap().as_usize(), Some(16));
        assert_eq!(tiny.get("classes").unwrap().as_usize(), Some(3));
        assert_eq!(tiny.get("labels"), Some(&Json::Null));
    }

    #[test]
    fn metrics_labelled_per_model() {
        let svc = mock_service();
        let resp = svc.handle(get("/metrics"));
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.contains("bitkernel_models_mounted 2"), "{body}");
        assert!(body.contains("bitkernel_mount_epoch{model=\"mock\"}"),
                "{body}");
        assert!(body.contains("bitkernel_requests_submitted{model=\"mock\"}"),
                "{body}");
        // Per-replica series carry both labels, well-formed.
        assert!(body.contains(
            "bitkernel_replica_requests{model=\"mock\",replica=\"0\"}"
        ), "{body}");
        assert!(!body.contains("}{"), "{body}");
    }

    #[test]
    fn classify_raw_body_uses_model_labels() {
        let svc = mock_service();
        let resp = svc.handle(post(None, vec![200u8; 3 * 32 * 32]));
        assert_eq!(resp.status, 200, "{}",
                   String::from_utf8_lossy(&resp.body));
        let body = String::from_utf8(resp.body).unwrap();
        let v = Json::parse(&body).unwrap();
        let class = v.get("class").unwrap().as_usize().unwrap();
        assert_eq!(v.get("label").unwrap().as_str(),
                   Some(format!("shape-{class}").as_str()));
        assert_eq!(v.get("model").unwrap().as_str(), Some("mock"));
        // Every classify reply names the generation that answered it.
        assert!(v.get("generation").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn classify_each_model_by_its_own_byte_count() {
        let svc = mock_service();
        // 16 bytes hit "tiny"; its label falls back to the numeric
        // class index (no label table).
        let resp = svc.handle(post(Some("tiny"), vec![10u8; 16]));
        assert_eq!(resp.status, 200, "{}",
                   String::from_utf8_lossy(&resp.body));
        let v = Json::parse(
            &String::from_utf8(resp.body).unwrap()).unwrap();
        assert_eq!(v.get("logits").unwrap().as_arr().map(<[Json]>::len),
                   Some(3));
        let class = v.get("class").unwrap().as_usize().unwrap();
        assert_eq!(v.get("label").unwrap().as_str(),
                   Some(class.to_string().as_str()));
        // The SAME 16-byte body against the 3072-byte default is a 400
        // naming both counts, not a panic.
        let resp = svc.handle(post(None, vec![10u8; 16]));
        assert_eq!(resp.status, 400);
        let err = String::from_utf8(resp.body).unwrap();
        assert!(err.contains("3072"), "{err}");
    }

    #[test]
    fn classify_json_body() {
        let svc = mock_service();
        let pixels: Vec<String> =
            (0..16).map(|i| (i * 16 % 256).to_string()).collect();
        let mut headers = BTreeMap::new();
        headers.insert("content-type".into(), "application/json".into());
        let mut req = post(Some("tiny"),
                           format!("{{\"pixels\":[{}]}}",
                                   pixels.join(",")).into_bytes());
        req.headers = headers;
        assert_eq!(svc.handle(req).status, 200);
    }

    #[test]
    fn classify_rejects_bad_sizes_and_unknown_model() {
        let svc = mock_service();
        assert_eq!(svc.handle(post(None, vec![0u8; 10])).status, 400);
        assert_eq!(
            svc.handle(post(Some("nope"), vec![0u8; 3 * 32 * 32])).status,
            404
        );
    }

    #[test]
    fn unknown_path_404() {
        let svc = mock_service();
        assert_eq!(svc.handle(get("/nope")).status, 404);
    }

    #[test]
    fn classify_timeout_ms_maps_to_504_and_bad_values_to_400() {
        let svc = mock_service();
        let mut req = post(None, vec![1u8; 3 * 32 * 32]);
        req.query.insert("timeout_ms".into(), "soon".into());
        assert_eq!(svc.handle(req).status, 400);

        // A model slow enough (200ms per batch) that a 1ms deadline
        // always expires before inference answers.
        let mut routers = BTreeMap::new();
        routers.insert(
            "slow".to_string(),
            Router::start(
                |_| Ok(Box::new(MockBackend::new(4, 200))
                       as Box<dyn bitkernel_backend::Backend>),
                RouterConfig { replicas: 1, ..RouterConfig::default() },
            )
            .unwrap(),
        );
        let svc = Service::new(routers, "slow");
        let mut req = post(None, vec![1u8; 3 * 32 * 32]);
        req.query.insert("timeout_ms".into(), "1".into());
        let resp = svc.handle(req);
        assert_eq!(resp.status, 504, "{}",
                   String::from_utf8_lossy(&resp.body));
        // The same model with a generous budget still answers 200.
        let mut req = post(None, vec![1u8; 3 * 32 * 32]);
        req.query.insert("timeout_ms".into(), "10000".into());
        let resp = svc.handle(req);
        assert_eq!(resp.status, 200, "{}",
                   String::from_utf8_lossy(&resp.body));
    }

    #[test]
    fn admin_verbs_are_403_when_disabled_get_allowed() {
        // Service::new freezes the model set: GETs work, mutations 403.
        let svc = mock_service();
        let resp = svc.handle(get("/models/mock"));
        assert_eq!(resp.status, 200);
        let v = Json::parse(&String::from_utf8(resp.body).unwrap())
            .unwrap();
        assert_eq!(v.get("state").unwrap().as_str(), Some("ready"));

        let mut req = get("/models/mock");
        req.method = "PUT".into();
        assert_eq!(svc.handle(req).status, 403);
        let mut req = get("/models/mock");
        req.method = "DELETE".into();
        assert_eq!(svc.handle(req).status, 403);
        let mut req = get("/models");
        req.method = "POST".into();
        req.body = b"{\"name\":\"x\",\"path\":\"/x.bkw\"}".to_vec();
        assert_eq!(svc.handle(req).status, 403);
        // The frozen set still serves.
        assert_eq!(svc.handle(post(Some("mock"),
                                   vec![1u8; 3 * 32 * 32])).status, 200);
    }

    #[test]
    fn no_default_model_is_a_404_with_hint() {
        let svc = Service::with_registry(
            ModelRegistry::new(RegistryConfig::default()),
            None,
            true,
        );
        let resp = svc.handle(post(None, vec![0u8; 4]));
        assert_eq!(resp.status, 404);
        assert!(String::from_utf8(resp.body)
            .unwrap()
            .contains("no default model"));
        // Mount with a malformed body is a 400, unknown names 404.
        let mut req = get("/models");
        req.method = "POST".into();
        req.body = b"not json".to_vec();
        assert_eq!(svc.handle(req).status, 400);
        assert_eq!(svc.handle(get("/models/ghost")).status, 404);
        let mut req = get("/models/ghost");
        req.method = "PUT".into();
        assert_eq!(svc.handle(req).status, 404);
        let mut req = get("/models/ghost");
        req.method = "DELETE".into();
        assert_eq!(svc.handle(req).status, 404);
    }

    #[test]
    fn classify_async_resolves_exactly_once() {
        let svc = mock_service();
        // Happy path: the callback delivers the same 200 the blocking
        // path would.
        let (tx, rx) = std::sync::mpsc::channel();
        let body = vec![200u8; 3 * 32 * 32];
        svc.classify_async(&BTreeMap::new(), None, &body, move |resp| {
            let _ = tx.send(resp);
        });
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.status, 200, "{}",
                   String::from_utf8_lossy(&resp.body));
        let v = Json::parse(
            &String::from_utf8(resp.body).unwrap()).unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("mock"));
        // Validation failure resolves inline (and exactly once): a
        // wrong-sized body never reaches the router.
        let (tx, rx) = std::sync::mpsc::channel();
        svc.classify_async(&BTreeMap::new(), None, &[0u8; 4], move |r| {
            let _ = tx.send(r.status);
        });
        assert_eq!(rx.try_recv(), Ok(400));
        assert!(rx.try_recv().is_err(), "callback ran twice");
        // Unknown model: typed 404 through the same callback.
        let mut q = BTreeMap::new();
        q.insert("model".to_string(), "ghost".to_string());
        let (tx, rx) = std::sync::mpsc::channel();
        svc.classify_async(&q, None, &[0u8; 4], move |r| {
            let _ = tx.send(r.status);
        });
        assert_eq!(rx.try_recv(), Ok(404));
    }

    #[test]
    fn metrics_include_front_end_series() {
        let svc = mock_service();
        svc.http_metrics().accepts.fetch_add(3, Ordering::Relaxed);
        svc.http_metrics().connections.fetch_add(1, Ordering::Relaxed);
        svc.http_metrics()
            .keepalive_reuses
            .fetch_add(2, Ordering::Relaxed);
        let body =
            String::from_utf8(svc.handle(get("/metrics")).body).unwrap();
        assert!(body.contains("bitkernel_http_connections 1"), "{body}");
        assert!(body.contains("bitkernel_http_accepts_total 3"),
                "{body}");
        assert!(body.contains("bitkernel_http_rejected_over_limit_total 0"),
                "{body}");
        assert!(body.contains("bitkernel_http_keepalive_reuses_total 2"),
                "{body}");
    }
}
