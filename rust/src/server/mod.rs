//! Minimal HTTP/1.1 front-end for the serving coordinator.
//!
//! Routes (shape-generic: every model's request/reply schema derives
//! from its own shape contract — see `GET /models`):
//! * `GET  /healthz`           — liveness
//! * `GET  /models`            — JSON list of mounted models: lifecycle
//!   state, weight generation, residency, and each one's input shape,
//!   byte count, class count, and label table
//! * `GET  /models/{name}`     — the same descriptor for one model
//! * `GET  /metrics`           — Prometheus-style counters (per model,
//!   plus the registry's mounted-models gauge and mount epochs)
//! * `POST /classify?model=m`  — body: the target model's `C*H*W` raw
//!   HWC uint8 pixels or JSON `{"pixels": [..C*H*W numbers..]}`;
//!   responds JSON `{"model", "generation", "class", "label",
//!   "latency_us", ...}` (label falls back to the numeric class index
//!   for label-less models)
//!
//! With the admin API enabled (`serve --admin`), the model set is
//! editable while traffic is in flight:
//! * `POST   /models`          — mount `{"name","path","lazy"?}`
//! * `PUT    /models/{name}`   — reload from the mounted path
//! * `DELETE /models/{name}`   — unmount (drain, then retire)
//!
//! Mutating verbs run builds off-thread and answer `202`; append
//! `?wait=1` for synchronous semantics.  Without `--admin` they are
//! `403` and the set is frozen.
//!
//! Built directly on std::net (offline: no hyper/tokio), with TWO
//! interchangeable front ends behind one [`serve`] entry point:
//!
//! * **Blocking** (default): one handler thread per connection from a
//!   fixed accept pool, keep-alive supported.  Simple, debuggable,
//!   fine up to a few hundred concurrent connections.
//! * **Event loop** (`--event-loop`, linux): an epoll reactor (or
//!   `--io-threads` of them) owns every connection non-blocking; see
//!   [`eventloop`] for the state machine and `benches/serve_load.rs`
//!   for the p50/p99/p999 comparison between the two.
//!
//! Behind each model name the [`ModelRegistry`] publishes
//! a replicated [`Router`](crate::coordinator::Router) behind a
//! hot-swap `Arc` handle; see `docs/SERVING.md` for the ops guide
//! (routes, knobs, backpressure, metrics, lifecycle) and
//! `docs/ARCHITECTURE.md` for the swap/drain design.

#[cfg(target_os = "linux")]
pub mod eventloop;
pub mod http;
pub mod registry;
pub mod service;

#[cfg(target_os = "linux")]
pub use eventloop::{
    Epoll, EV_ERR, EV_ET, EV_HUP, EV_IN, EV_OUT, EV_RDHUP,
};
pub use http::{
    http_call, http_call_retry, http_call_timeout, HttpHead,
    HttpRequest, HttpResponse,
};
pub use registry::{
    ModelContract, ModelEntry, ModelRegistry, ModelState, ModelStatus,
    RegistryConfig, RegistryError,
};
pub use service::{serve, HttpMetrics, ServeOptions, Service};
