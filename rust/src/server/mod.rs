//! Minimal HTTP/1.1 front-end for the serving coordinator.
//!
//! Routes:
//! * `GET  /healthz`           — liveness
//! * `GET  /models`            — JSON list of served models
//! * `GET  /metrics`           — Prometheus-style counters (per model)
//! * `POST /classify?model=m`  — body: 3072 raw HWC uint8 pixels
//!   (32x32x3) or JSON `{"pixels": [..3072 ints..]}`; responds JSON
//!   `{"class": c, "label": name, "latency_us": t}`
//!
//! Built directly on std::net (offline: no hyper/tokio); one handler
//! thread per connection from a fixed accept pool, keep-alive supported.
//! Behind each model name sits a replicated
//! [`Router`](crate::coordinator::Router); see `docs/SERVING.md` for
//! the ops guide (routes, knobs, backpressure, metrics).

pub mod http;
pub mod service;

pub use http::{HttpRequest, HttpResponse};
pub use service::{serve, ServeOptions, Service, CLASS_NAMES};
