//! Minimal HTTP/1.1 front-end for the serving coordinator.
//!
//! Routes (shape-generic: every model's request/reply schema derives
//! from its own shape contract — see `GET /models`):
//! * `GET  /healthz`           — liveness
//! * `GET  /models`            — JSON list of mounted models: lifecycle
//!   state, weight generation, residency, and each one's input shape,
//!   byte count, class count, and label table
//! * `GET  /models/{name}`     — the same descriptor for one model
//! * `GET  /metrics`           — Prometheus-style counters (per model,
//!   plus the registry's mounted-models gauge and mount epochs)
//! * `POST /classify?model=m`  — body: the target model's `C*H*W` raw
//!   HWC uint8 pixels or JSON `{"pixels": [..C*H*W numbers..]}`;
//!   responds JSON `{"model", "generation", "class", "label",
//!   "latency_us", ...}` (label falls back to the numeric class index
//!   for label-less models)
//!
//! With the admin API enabled (`serve --admin`), the model set is
//! editable while traffic is in flight:
//! * `POST   /models`          — mount `{"name","path","lazy"?}`
//! * `PUT    /models/{name}`   — reload from the mounted path
//! * `DELETE /models/{name}`   — unmount (drain, then retire)
//!
//! Mutating verbs run builds off-thread and answer `202`; append
//! `?wait=1` for synchronous semantics.  Without `--admin` they are
//! `403` and the set is frozen.
//!
//! Built directly on std::net (offline: no hyper/tokio); one handler
//! thread per connection from a fixed accept pool, keep-alive
//! supported.  Behind each model name the [`ModelRegistry`] publishes
//! a replicated [`Router`](crate::coordinator::Router) behind a
//! hot-swap `Arc` handle; see `docs/SERVING.md` for the ops guide
//! (routes, knobs, backpressure, metrics, lifecycle) and
//! `docs/ARCHITECTURE.md` for the swap/drain design.

pub mod http;
pub mod registry;
pub mod service;

pub use http::{http_call, http_call_retry, HttpRequest, HttpResponse};
pub use registry::{
    ModelContract, ModelEntry, ModelRegistry, ModelState, ModelStatus,
    RegistryConfig, RegistryError,
};
pub use service::{serve, ServeOptions, Service};
