//! Minimal HTTP/1.1 front-end for the serving coordinator.
//!
//! Routes (shape-generic: every model's request/reply schema derives
//! from its own shape contract — see `GET /models`):
//! * `GET  /healthz`           — liveness
//! * `GET  /models`            — JSON list of served models with each
//!   one's input shape, byte count, class count, and label table
//! * `GET  /metrics`           — Prometheus-style counters (per model)
//! * `POST /classify?model=m`  — body: the target model's `C*H*W` raw
//!   HWC uint8 pixels or JSON `{"pixels": [..C*H*W numbers..]}`;
//!   responds JSON `{"model", "class", "label", "latency_us", ...}`
//!   (label falls back to the numeric class index for label-less
//!   models)
//!
//! Built directly on std::net (offline: no hyper/tokio); one handler
//! thread per connection from a fixed accept pool, keep-alive supported.
//! Behind each model name sits a replicated
//! [`Router`](crate::coordinator::Router); see `docs/SERVING.md` for
//! the ops guide (routes, knobs, backpressure, metrics).

pub mod http;
pub mod service;

pub use http::{HttpRequest, HttpResponse};
pub use service::{serve, ServeOptions, Service};
