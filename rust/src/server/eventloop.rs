//! Non-blocking epoll front end (`serve --event-loop`).
//!
//! The blocking front end parks one pool thread per open connection;
//! past a few hundred keep-alive clients the pool is the bottleneck
//! long before the model is.  This module serves every connection
//! from one or a few **reactor** threads instead:
//!
//! ```text
//!   listener ──accept──▶ reactor 0 ──round-robin──▶ reactor 1..N
//!                         │  epoll_wait (edge-triggered)
//!                         ▼
//!      per-connection state machine
//!        ReadHead ─▶ ReadBody ─▶ dispatch ─▶ Write ─▶ ReadHead…
//!                                  │
//!            classify ─▶ Router::submit_callback (continuous batch)
//!            other     ─▶ auxiliary thread pool (admin may block)
//!                                  │
//!            completion queue + waker ─▶ reactor writes response
//! ```
//!
//! Design notes:
//!
//! * **No dependencies.**  The four epoll syscalls are declared
//!   inline (same discipline as `model/mmap.rs`); the waker is a
//!   `UnixStream` pair, the slab and timer wheel are hand-rolled.
//! * **Edge-triggered.**  Each socket is registered once with
//!   `EPOLLIN | EPOLLOUT | EPOLLET | EPOLLRDHUP`; readiness is
//!   tracked in the connection (`writable`) and every read/write
//!   drains until `WouldBlock`, as ET requires.
//! * **One request in flight per connection.**  Pipelined requests
//!   queue in the read buffer and are answered strictly in order —
//!   same observable semantics as the blocking front end.
//! * **The reactor never blocks.**  Classify dispatches through
//!   [`Service::classify_async`] (resolved by a replica worker);
//!   every other route — admin `?wait=1` can legally block for a
//!   minute — runs on a small auxiliary pool.  Either way the
//!   response comes back through a completion queue and the waker.
//! * **Bounded.**  `--max-connections` is enforced at accept (503 +
//!   `Retry-After`), buffers are capped by the shared HTTP parsing
//!   limits, and a lazy timer wheel closes connections idle past
//!   `--idle-timeout-ms`.
//!
//! The [`Epoll`] wrapper is public: `benches/serve_load.rs` reuses it
//! to multiplex thousands of client connections from one thread.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::utils::threadpool::ThreadPool;
use crate::{log_debug, log_error, log_info};

use super::http::{HttpHead, HttpResponse, MAX_BODY};
use super::service::{ServeOptions, Service};

/// `EPOLLIN`: the fd has bytes to read.
pub const EV_IN: u32 = 0x001;
/// `EPOLLOUT`: the fd accepts writes again.
pub const EV_OUT: u32 = 0x004;
/// `EPOLLERR`: error condition (always reported, never requested).
pub const EV_ERR: u32 = 0x008;
/// `EPOLLHUP`: hangup (always reported, never requested).
pub const EV_HUP: u32 = 0x010;
/// `EPOLLRDHUP`: peer shut down its write half.
pub const EV_RDHUP: u32 = 0x2000;
/// `EPOLLET`: edge-triggered delivery.
pub const EV_ET: u32 = 1 << 31;

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    /// Mirrors the kernel's `struct epoll_event`.  The kernel packs
    /// it on x86-64 only; everywhere else natural alignment applies.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub token: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// Most events drained per `epoll_wait` call.
const WAIT_BATCH: usize = 512;

/// A thin owned epoll instance.  Register fds with a caller-chosen
/// `u64` token; [`Epoll::wait`] reports `(events, token)` pairs.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    /// Create a close-on-exec epoll instance.
    pub fn new() -> Result<Self> {
        // SAFETY: plain syscall, no pointers.
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        anyhow::ensure!(
            fd >= 0,
            "epoll_create1: {}",
            std::io::Error::last_os_error()
        );
        Ok(Self { fd })
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: RawFd, events: u32,
           token: u64) -> Result<()> {
        let mut ev = sys::EpollEvent { events, token };
        // SAFETY: `ev` outlives the call; the kernel copies it.
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        anyhow::ensure!(
            rc == 0,
            "epoll_ctl: {}",
            std::io::Error::last_os_error()
        );
        Ok(())
    }

    /// Register `fd` for `events`, delivered with `token`.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change the registered interest set for `fd`.
    pub fn modify(&self, fd: RawFd, events: u32, token: u64)
                  -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` (closing an fd deregisters it implicitly; this
    /// is for keeping an fd open but silent).
    pub fn del(&self, fd: RawFd) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (`-1` = forever) and fill `out` with
    /// `(events, token)` pairs.  A signal interruption reports zero
    /// events rather than an error.
    pub fn wait(&self, out: &mut Vec<(u32, u64)>, timeout_ms: i32)
                -> Result<usize> {
        out.clear();
        let mut buf =
            [sys::EpollEvent { events: 0, token: 0 }; WAIT_BATCH];
        // SAFETY: `buf` has WAIT_BATCH writable slots.
        let n = unsafe {
            sys::epoll_wait(
                self.fd,
                buf.as_mut_ptr(),
                WAIT_BATCH as std::os::raw::c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            anyhow::bail!("epoll_wait: {e}");
        }
        for ev in &buf[..n as usize] {
            // Field copies (not references) are fine on packed types.
            let events = ev.events;
            let token = ev.token;
            out.push((events, token));
        }
        Ok(n as usize)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: fd came from epoll_create1 and is closed only here.
        unsafe {
            sys::close(self.fd);
        }
    }
}

/// Token carried by the listening socket.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Token carried by the reactor's waker pipe.
const TOKEN_WAKER: u64 = u64::MAX - 1;

/// Pack a slab index and generation into an epoll token.
fn conn_token(idx: usize, gen: u64) -> u64 {
    ((gen & 0xffff_ffff) << 32) | idx as u64
}

/// Unpack [`conn_token`].
fn split_token(token: u64) -> (usize, u64) {
    ((token & 0xffff_ffff) as usize, token >> 32)
}

/// Per-connection parse state.
enum ConnState {
    /// Accumulating request head bytes.
    ReadHead,
    /// Head parsed; waiting for `body_len` body bytes.
    ReadBody { head: HttpHead, body_len: usize },
}

/// One connection owned by a reactor.
struct Conn {
    stream: TcpStream,
    gen: u64,
    state: ConnState,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    written: usize,
    /// Last kernel-reported writability; cleared on `WouldBlock`,
    /// set again by `EPOLLOUT` (edge-triggered contract).
    writable: bool,
    /// An async request is outstanding; parsing is paused until its
    /// completion lands (responses stay in request order).
    inflight: bool,
    /// Keep-alive decision of the request currently in flight.
    resp_keep_alive: bool,
    /// Close once `write_buf` drains (error or `Connection: close`).
    close_after_write: bool,
    /// Peer shut down its write half (`EPOLLRDHUP` / read 0).
    peer_closed: bool,
    /// Refreshed on every byte received and every completion.
    last_activity: Instant,
    /// Requests dispatched on this connection.
    served: u64,
}

/// Generation-checked connection slab.  Tokens from a previous tenant
/// of a slot fail the generation check instead of touching the new
/// connection (classic ABA protection).
struct Slab {
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
}

impl Slab {
    fn new() -> Self {
        Self { conns: Vec::new(), free: Vec::new(), next_gen: 0 }
    }

    fn insert(&mut self, mut conn: Conn) -> (usize, u64) {
        self.next_gen = (self.next_gen + 1) & 0xffff_ffff;
        let gen = self.next_gen;
        conn.gen = gen;
        let idx = match self.free.pop() {
            Some(i) => {
                self.conns[i] = Some(conn);
                i
            }
            None => {
                self.conns.push(Some(conn));
                self.conns.len() - 1
            }
        };
        (idx, gen)
    }

    fn get_mut(&mut self, idx: usize) -> Option<&mut Conn> {
        self.conns.get_mut(idx).and_then(Option::as_mut)
    }

    fn get_checked(&mut self, idx: usize, gen: u64)
                   -> Option<&mut Conn> {
        self.get_mut(idx).filter(|c| c.gen == gen)
    }

    fn remove(&mut self, idx: usize) -> Option<Conn> {
        let conn = self.conns.get_mut(idx).and_then(Option::take);
        if conn.is_some() {
            self.free.push(idx);
        }
        conn
    }

    fn len(&self) -> usize {
        self.conns.len() - self.free.len()
    }
}

/// Lazy hashed timer wheel for idle timeouts.  Entries fire at slot
/// granularity; stale entries (the connection saw activity since
/// insertion) are re-filed at their true deadline instead of closed,
/// so refreshing a timer is free.
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    granularity: Duration,
    cursor: usize,
    cursor_time: Instant,
}

impl TimerWheel {
    fn new(idle_timeout: Duration, now: Instant) -> Self {
        let granularity = std::cmp::max(
            idle_timeout / 32,
            Duration::from_millis(10),
        );
        Self {
            slots: vec![Vec::new(); 64],
            granularity,
            cursor: 0,
            cursor_time: now,
        }
    }

    /// File `(idx, gen)` to fire at or shortly after `deadline`.
    fn insert(&mut self, idx: usize, gen: u64, deadline: Instant) {
        let ticks = deadline
            .saturating_duration_since(self.cursor_time)
            .as_nanos()
            / self.granularity.as_nanos().max(1);
        let off = (ticks as usize).clamp(1, self.slots.len() - 1);
        let slot = (self.cursor + off) % self.slots.len();
        self.slots[slot].push((idx, gen));
    }

    /// How long `epoll_wait` may sleep before the next tick is due.
    fn until_tick(&self, now: Instant) -> Duration {
        (self.cursor_time + self.granularity)
            .saturating_duration_since(now)
    }

    /// Advance the cursor up to `now`, appending everything due to
    /// `due`.
    fn tick(&mut self, now: Instant, due: &mut Vec<(usize, u64)>) {
        while now >= self.cursor_time + self.granularity {
            self.cursor = (self.cursor + 1) % self.slots.len();
            self.cursor_time += self.granularity;
            due.append(&mut self.slots[self.cursor]);
        }
    }
}

/// The cross-thread half of a reactor: completion queue, injected
/// connections (from the accepting reactor), and the waker that pops
/// `epoll_wait`.
struct ReactorShared {
    completions: Mutex<Vec<(u64, HttpResponse)>>,
    injected: Mutex<VecDeque<TcpStream>>,
    /// Write half of the waker pair (non-blocking: a full pipe means
    /// a wake is already pending, which is all we need).
    waker: UnixStream,
}

impl ReactorShared {
    fn wake(&self) {
        let _ = (&self.waker).write(&[1u8]);
    }

    fn complete(&self, token: u64, resp: HttpResponse) {
        self.completions.lock().unwrap().push((token, resp));
        self.wake();
    }

    fn inject(&self, stream: TcpStream) {
        self.injected.lock().unwrap().push_back(stream);
        self.wake();
    }
}

/// One reactor thread: an epoll instance plus every connection it
/// owns.  Reactor 0 additionally owns the listener and hands accepted
/// sockets round-robin to the full reactor set (itself included).
struct Reactor {
    epoll: Epoll,
    slab: Slab,
    wheel: TimerWheel,
    shared: Arc<ReactorShared>,
    waker_rx: UnixStream,
    service: Arc<Service>,
    pool: Arc<ThreadPool>,
    stop: Arc<AtomicBool>,
    /// Open connections across ALL reactors (the accept-side cap).
    active: Arc<AtomicUsize>,
    idle_timeout: Duration,
    max_connections: usize,
    /// Reactor 0 only.
    listener: Option<TcpListener>,
    /// Reactor 0 only: every reactor's shared half, for round-robin.
    peers: Vec<Arc<ReactorShared>>,
    next_rr: usize,
}

impl Reactor {
    fn new(
        shared: Arc<ReactorShared>,
        waker_rx: UnixStream,
        service: Arc<Service>,
        pool: Arc<ThreadPool>,
        stop: Arc<AtomicBool>,
        active: Arc<AtomicUsize>,
        opts: &ServeOptions,
    ) -> Result<Self> {
        let epoll = Epoll::new()?;
        waker_rx.set_nonblocking(true)?;
        epoll.add(
            waker_rx.as_raw_fd(),
            EV_IN | EV_ET,
            TOKEN_WAKER,
        )?;
        Ok(Self {
            epoll,
            slab: Slab::new(),
            wheel: TimerWheel::new(opts.idle_timeout, Instant::now()),
            shared,
            waker_rx,
            service,
            pool,
            stop,
            active,
            idle_timeout: opts.idle_timeout,
            max_connections: opts.max_connections,
            listener: None,
            peers: Vec::new(),
            next_rr: 0,
        })
    }

    /// Main loop: wait, handle events, drain queues, tick timers.
    fn run(&mut self) {
        let mut events: Vec<(u32, u64)> = Vec::new();
        let mut due: Vec<(usize, u64)> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            let now = Instant::now();
            let wait = self
                .wheel
                .until_tick(now)
                .min(Duration::from_millis(200));
            let timeout_ms = wait.as_millis() as i32;
            if let Err(e) = self.epoll.wait(&mut events, timeout_ms) {
                log_error!("reactor: {e:#}");
                break;
            }
            for &(ev, token) in &events {
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.drain_waker(),
                    _ => self.conn_event(token, ev),
                }
            }
            self.drain_injected();
            self.drain_completions();
            let now = Instant::now();
            due.clear();
            self.wheel.tick(now, &mut due);
            for &(idx, gen) in &due {
                self.timer_fire(idx, gen, now);
            }
        }
    }

    /// Accept until `WouldBlock`; shed over the global cap; hand the
    /// rest round-robin to the reactor set.
    fn accept_ready(&mut self) {
        loop {
            let accepted = match self.listener.as_ref() {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((mut stream, _peer)) => {
                    let m = self.service.http_metrics();
                    if self.active.load(Ordering::Relaxed)
                        >= self.max_connections
                    {
                        m.rejected_over_limit
                            .fetch_add(1, Ordering::Relaxed);
                        // Accepted sockets are blocking by default;
                        // this small write is best-effort.
                        let _ = HttpResponse::text(
                            503,
                            "server at connection capacity\n",
                        )
                        .with_header("Retry-After", "1")
                        .write(&mut stream, false);
                        continue;
                    }
                    m.accepts.fetch_add(1, Ordering::Relaxed);
                    m.connections.fetch_add(1, Ordering::Relaxed);
                    self.active.fetch_add(1, Ordering::Relaxed);
                    let target = self.next_rr % self.peers.len().max(1);
                    self.next_rr = self.next_rr.wrapping_add(1);
                    if target == 0 {
                        self.install(stream);
                    } else {
                        self.peers[target].inject(stream);
                    }
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::WouldBlock =>
                {
                    return
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log_error!("accept: {e}");
                    self.stop.store(true, Ordering::Relaxed);
                    return;
                }
            }
        }
    }

    /// Take ownership of a connection: nonblocking, slab slot, epoll
    /// registration, idle timer.
    fn install(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            self.release_counts();
            return;
        }
        let now = Instant::now();
        let fd = stream.as_raw_fd();
        let (idx, gen) = self.slab.insert(Conn {
            stream,
            gen: 0,
            state: ConnState::ReadHead,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            written: 0,
            writable: true,
            inflight: false,
            resp_keep_alive: true,
            close_after_write: false,
            peer_closed: false,
            last_activity: now,
            served: 0,
        });
        let interest = EV_IN | EV_OUT | EV_ET | EV_RDHUP;
        if self
            .epoll
            .add(fd, interest, conn_token(idx, gen))
            .is_err()
        {
            self.slab.remove(idx);
            self.release_counts();
            return;
        }
        self.wheel.insert(idx, gen, now + self.idle_timeout);
    }

    /// Decrement the open-connection count and gauge (used when a
    /// connection dies before or after living in the slab).
    fn release_counts(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
        self.service
            .http_metrics()
            .connections
            .fetch_sub(1, Ordering::Relaxed);
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.waker_rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(_) => return,
            }
        }
    }

    fn drain_injected(&mut self) {
        loop {
            let stream =
                self.shared.injected.lock().unwrap().pop_front();
            match stream {
                Some(s) => self.install(s),
                None => return,
            }
        }
    }

    fn drain_completions(&mut self) {
        let completions = std::mem::take(
            &mut *self.shared.completions.lock().unwrap(),
        );
        for (token, resp) in completions {
            let (idx, gen) = split_token(token);
            let Some(conn) = self.slab.get_checked(idx, gen) else {
                // The connection died while the request was in
                // flight; the generation check drops the orphan.
                continue;
            };
            let keep = conn.resp_keep_alive && !conn.peer_closed;
            conn.write_buf.extend_from_slice(&resp.to_bytes(keep));
            conn.inflight = false;
            conn.last_activity = Instant::now();
            if !keep {
                conn.close_after_write = true;
            }
            self.flush_write(idx);
            if self
                .slab
                .get_mut(idx)
                .is_some_and(|c| !c.close_after_write)
            {
                // Pipelined bytes may already hold the next request
                // (in the buffer, or parked in the kernel if the
                // in-flight cap paused reading) — resume the drain.
                self.on_readable(idx);
            }
        }
    }

    /// One epoll event on a connection token.
    fn conn_event(&mut self, token: u64, ev: u32) {
        let (idx, gen) = split_token(token);
        let Some(conn) = self.slab.get_checked(idx, gen) else {
            return;
        };
        if ev & (EV_ERR | EV_HUP) != 0 {
            self.close(idx);
            return;
        }
        if ev & EV_OUT != 0 {
            conn.writable = true;
        }
        if ev & EV_RDHUP != 0 {
            conn.peer_closed = true;
        }
        if ev & EV_IN != 0 {
            self.on_readable(idx);
        } else if ev & EV_RDHUP != 0 {
            // Half-close with no data: finish what is pending, close
            // the rest.
            self.maybe_close_half_open(idx);
        }
        if self.slab.get_mut(idx).is_some() {
            self.flush_write(idx);
        }
    }

    /// A peer that half-closed and has nothing outstanding (no
    /// in-flight request, nothing to write) is done.
    fn maybe_close_half_open(&mut self, idx: usize) {
        let Some(conn) = self.slab.get_mut(idx) else { return };
        if conn.peer_closed
            && !conn.inflight
            && conn.write_buf.is_empty()
        {
            self.close(idx);
        }
    }

    /// Drain the socket (edge-triggered: until `WouldBlock`), then
    /// advance the parser.
    fn on_readable(&mut self, idx: usize) {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            let Some(conn) = self.slab.get_mut(idx) else { return };
            if conn.close_after_write {
                // Discarding input; stop pulling bytes — the close
                // lands once the error response flushes.
                return;
            }
            if conn.inflight
                && conn.read_buf.len() >= MAX_BODY + 16 * 1024
            {
                // A peer pipelining faster than its requests resolve
                // cannot grow the buffer without bound: stop reading
                // (bytes back up in the kernel) until the in-flight
                // request completes — the completion path resumes
                // the drain, which ET alone would not.
                break;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.read_buf.extend_from_slice(&chunk[..n]);
                    conn.last_activity = Instant::now();
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::WouldBlock =>
                {
                    break
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log_debug!("read: {e}");
                    self.close(idx);
                    return;
                }
            }
        }
        self.advance(idx);
        self.maybe_close_half_open(idx);
    }

    /// Run the parse state machine over the read buffer until it
    /// needs more bytes, a request dispatches (one in flight at a
    /// time), or the connection errors out.
    fn advance(&mut self, idx: usize) {
        loop {
            let Some(conn) = self.slab.get_mut(idx) else { return };
            if conn.inflight || conn.close_after_write {
                return;
            }
            match &conn.state {
                ConnState::ReadHead => {
                    match HttpHead::parse(&conn.read_buf) {
                        Err(e) => {
                            self.error_close(idx, &format!("{e:#}"));
                            return;
                        }
                        Ok(None) => {
                            // Incomplete head; a half-closed peer can
                            // never finish it.
                            if conn.peer_closed {
                                self.close(idx);
                            }
                            return;
                        }
                        Ok(Some((head, consumed))) => {
                            conn.read_buf.drain(..consumed);
                            match head.body_len() {
                                Ok(body_len) => {
                                    conn.state = ConnState::ReadBody {
                                        head,
                                        body_len,
                                    };
                                }
                                Err(e) => {
                                    self.error_close(
                                        idx,
                                        &format!("{e:#}"),
                                    );
                                    return;
                                }
                            }
                        }
                    }
                }
                ConnState::ReadBody { body_len, .. } => {
                    let body_len = *body_len;
                    if conn.read_buf.len() < body_len {
                        // Mid-body disconnect: the request can never
                        // complete, so nothing ever reaches a
                        // replica — just fold the connection.
                        if conn.peer_closed {
                            self.close(idx);
                        }
                        return;
                    }
                    let rest = conn.read_buf.split_off(body_len);
                    let body = std::mem::replace(
                        &mut conn.read_buf,
                        rest,
                    );
                    let state = std::mem::replace(
                        &mut conn.state,
                        ConnState::ReadHead,
                    );
                    let ConnState::ReadBody { head, .. } = state
                    else {
                        unreachable!("matched ReadBody above");
                    };
                    self.dispatch(idx, head, body);
                    return;
                }
            }
        }
    }

    /// Hand one complete request to the service.  Classify goes
    /// through the router's callback path (resolved by a replica
    /// worker); everything else may block (admin `?wait=1`) and runs
    /// on the auxiliary pool.  Both resolve through the completion
    /// queue, keyed by this connection's generation token.
    fn dispatch(&mut self, idx: usize, head: HttpHead, body: Vec<u8>) {
        let Some(conn) = self.slab.get_mut(idx) else { return };
        let gen = conn.gen;
        if conn.served > 0 {
            self.service
                .http_metrics()
                .keepalive_reuses
                .fetch_add(1, Ordering::Relaxed);
        }
        conn.served += 1;
        conn.resp_keep_alive = head.wants_keep_alive();
        conn.inflight = true;
        let token = conn_token(idx, gen);
        let shared = Arc::clone(&self.shared);
        if head.method == "POST" && head.path == "/classify" {
            let content_type =
                head.headers.get("content-type").map(String::as_str);
            self.service.classify_async(
                &head.query,
                content_type,
                &body,
                move |resp| shared.complete(token, resp),
            );
        } else {
            let service = Arc::clone(&self.service);
            let req = head.into_request(body);
            self.pool.execute(move || {
                let resp = service.handle(req);
                shared.complete(token, resp);
            });
        }
    }

    /// Queue a 400, discard buffered input, close after the flush —
    /// a framing error leaves unknown bytes on the stream, so the
    /// connection cannot be reused (same rule as the blocking path).
    fn error_close(&mut self, idx: usize, msg: &str) {
        let Some(conn) = self.slab.get_mut(idx) else { return };
        let resp = HttpResponse::json(
            400,
            crate::utils::json::Json::obj(vec![(
                "error",
                crate::utils::json::Json::Str(msg.to_string()),
            )])
            .to_string(),
        );
        conn.write_buf.extend_from_slice(&resp.to_bytes(false));
        conn.read_buf.clear();
        conn.close_after_write = true;
        self.flush_write(idx);
    }

    /// Push buffered response bytes while the socket accepts them;
    /// on `WouldBlock` the `EPOLLOUT` edge resumes the flush.
    fn flush_write(&mut self, idx: usize) {
        loop {
            let Some(conn) = self.slab.get_mut(idx) else { return };
            if conn.write_buf.is_empty() {
                break;
            }
            if !conn.writable {
                return; // wait for EPOLLOUT
            }
            if conn.written == conn.write_buf.len() {
                conn.write_buf.clear();
                conn.written = 0;
                break;
            }
            match conn.stream.write(&conn.write_buf[conn.written..]) {
                Ok(0) => {
                    self.close(idx);
                    return;
                }
                Ok(n) => conn.written += n,
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::WouldBlock =>
                {
                    conn.writable = false;
                    return;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log_debug!("write: {e}");
                    self.close(idx);
                    return;
                }
            }
        }
        let Some(conn) = self.slab.get_mut(idx) else { return };
        if conn.close_after_write && conn.write_buf.is_empty() {
            self.close(idx);
        }
    }

    /// A timer-wheel entry came due.  Stale entries (activity since
    /// filing, or a request in flight) are re-filed at their true
    /// deadline; genuinely idle connections close.
    fn timer_fire(&mut self, idx: usize, gen: u64, now: Instant) {
        let Some(conn) = self.slab.get_checked(idx, gen) else {
            return;
        };
        let deadline = conn.last_activity + self.idle_timeout;
        if conn.inflight {
            self.wheel.insert(idx, gen, now + self.idle_timeout);
        } else if now >= deadline {
            log_debug!("closing idle connection");
            self.close(idx);
        } else {
            self.wheel.insert(idx, gen, deadline);
        }
    }

    /// Remove and drop a connection (dropping the stream closes the
    /// fd, which also deregisters it from epoll).
    fn close(&mut self, idx: usize) {
        if self.slab.remove(idx).is_some() {
            self.release_counts();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        // Release the accounting for every connection still open at
        // shutdown so the gauge reads 0 after the front end exits.
        for idx in 0..self.slab.conns.len() {
            self.close(idx);
        }
    }
}

/// Serve with the epoll front end until `stop` flips true.  Reactor 0
/// runs on the calling thread and owns the listener; `--io-threads`
/// minus one additional reactors run on their own threads and receive
/// accepted connections round-robin.
pub(super) fn serve_event_loop(
    service: Arc<Service>,
    opts: &ServeOptions,
    stop: Arc<AtomicBool>,
    ready_tx: Option<mpsc::Sender<SocketAddr>>,
) -> Result<()> {
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let reactors = opts.io_threads.max(1);
    log_info!(
        "serving on http://{addr} (event loop, {reactors} reactor(s), \
         models: {:?})",
        service.models()
    );
    if let Some(tx) = ready_tx {
        let _ = tx.send(addr);
    }
    let pool = Arc::new(ThreadPool::new(opts.threads.max(1)));
    let active = Arc::new(AtomicUsize::new(0));

    let mut shareds = Vec::with_capacity(reactors);
    let mut waker_rxs = Vec::with_capacity(reactors);
    for _ in 0..reactors {
        let (rx, tx) = UnixStream::pair().context("waker pair")?;
        tx.set_nonblocking(true)?;
        shareds.push(Arc::new(ReactorShared {
            completions: Mutex::new(Vec::new()),
            injected: Mutex::new(VecDeque::new()),
            waker: tx,
        }));
        waker_rxs.push(rx);
    }

    let mut handles = Vec::new();
    for (r, rx) in waker_rxs.drain(1..).enumerate() {
        let mut reactor = Reactor::new(
            Arc::clone(&shareds[r + 1]),
            rx,
            Arc::clone(&service),
            Arc::clone(&pool),
            Arc::clone(&stop),
            Arc::clone(&active),
            opts,
        )?;
        handles.push(
            std::thread::Builder::new()
                .name(format!("reactor-{}", r + 1))
                .spawn(move || reactor.run())
                .context("spawn reactor")?,
        );
    }

    let mut r0 = Reactor::new(
        Arc::clone(&shareds[0]),
        waker_rxs.pop().expect("reactor 0 waker"),
        Arc::clone(&service),
        pool,
        Arc::clone(&stop),
        active,
        opts,
    )?;
    r0.epoll.add(
        listener.as_raw_fd(),
        EV_IN | EV_ET,
        TOKEN_LISTENER,
    )?;
    r0.listener = Some(listener);
    r0.peers = shareds.clone();
    r0.run();
    drop(r0);

    // Reactor 0 exiting (external stop or accept failure) takes the
    // whole front end down.
    stop.store(true, Ordering::Relaxed);
    for s in &shareds {
        s.wake();
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip_protects_generations() {
        let t = conn_token(42, 7);
        assert_eq!(split_token(t), (42, 7));
        let t2 = conn_token(42, 8);
        assert_ne!(t, t2, "new tenant must invalidate old tokens");
        assert_ne!(t, TOKEN_LISTENER);
        assert_ne!(t, TOKEN_WAKER);
    }

    #[test]
    fn epoll_reports_readiness_edges() {
        let ep = Epoll::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        ep.add(a.as_raw_fd(), EV_IN | EV_ET, 99).unwrap();
        let mut events = Vec::new();
        // Nothing written yet: no event inside a short wait.
        ep.wait(&mut events, 20).unwrap();
        assert!(events.is_empty(), "{events:?}");
        (&b).write_all(&[1u8]).unwrap();
        ep.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        let (ev, token) = events[0];
        assert_eq!(token, 99);
        assert_ne!(ev & EV_IN, 0);
        // Edge-triggered: without a new write (or a drain), the same
        // edge is not reported twice.
        ep.wait(&mut events, 20).unwrap();
        assert!(events.is_empty(), "{events:?}");
    }

    #[test]
    fn slab_generations_invalidate_removed_slots() {
        // Direct slab surgery without sockets: use a dummy pair.
        let mk = || {
            let (s, _keep) = {
                let l = std::net::TcpListener::bind("127.0.0.1:0")
                    .unwrap();
                let addr = l.local_addr().unwrap();
                let c = TcpStream::connect(addr).unwrap();
                let (srv, _) = l.accept().unwrap();
                (srv, c)
            };
            Conn {
                stream: s,
                gen: 0,
                state: ConnState::ReadHead,
                read_buf: Vec::new(),
                write_buf: Vec::new(),
                written: 0,
                writable: true,
                inflight: false,
                resp_keep_alive: true,
                close_after_write: false,
                peer_closed: false,
                last_activity: Instant::now(),
                served: 0,
            }
        };
        let mut slab = Slab::new();
        let (idx, gen) = slab.insert(mk());
        assert!(slab.get_checked(idx, gen).is_some());
        slab.remove(idx);
        assert!(slab.get_checked(idx, gen).is_none());
        // The slot is reused with a fresh generation; the old token
        // still fails.
        let (idx2, gen2) = slab.insert(mk());
        assert_eq!(idx2, idx, "freelist reuses the slot");
        assert_ne!(gen2, gen);
        assert!(slab.get_checked(idx, gen).is_none());
        assert!(slab.get_checked(idx2, gen2).is_some());
        assert_eq!(slab.len(), 1);
    }

    #[test]
    fn timer_wheel_fires_at_deadline_and_not_before() {
        let t0 = Instant::now();
        let mut wheel =
            TimerWheel::new(Duration::from_millis(320), t0);
        // granularity = max(320/32, 10) = 10ms
        wheel.insert(3, 1, t0 + Duration::from_millis(100));
        let mut due = Vec::new();
        wheel.tick(t0 + Duration::from_millis(50), &mut due);
        assert!(due.is_empty(), "fired {:?} early", due);
        wheel.tick(t0 + Duration::from_millis(200), &mut due);
        assert_eq!(due, vec![(3, 1)]);
        // Far deadlines cap at the wheel span and simply re-file on
        // fire (lazy): filing works without panicking.
        wheel.insert(4, 2, t0 + Duration::from_secs(3600));
        due.clear();
        wheel.tick(t0 + Duration::from_secs(1), &mut due);
        assert_eq!(due, vec![(4, 2)]);
    }
}
