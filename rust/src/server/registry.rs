//! Dynamic model registry: the live `name -> Router` set behind a
//! hot-swap handle, with mount / reload / unmount lifecycle.
//!
//! PR 3 gave routers a lossless drain ([`Router::shutdown`] and the
//! identical `Drop` path) and PR 5 gave every model a typed shape
//! contract; this module turns the static map `Service` used to own
//! into a **lifecycle subsystem**:
//!
//! ```text
//!                     mount (off-thread build)
//!        absent ───────────────────────────────▶ loading
//!                                                  │ build ok
//!            ┌──── failed ◀── build error ─────────┤
//!            │ reload                              ▼
//!            └────────────▶ loading ──swap──▶   ready ──┐
//!                            (old router        ▲       │ unmount
//!                             keeps serving)    └───────┘    │
//!                                                  draining ─┴─▶ absent
//! ```
//!
//! **Swap discipline.**  The registry publishes each model's pipeline
//! as an `Arc<Router>`.  `router_for` hands a clone to every request,
//! so a reload can atomically replace the published handle while
//! admitted requests keep their generation's router alive; the retired
//! router is parked on a detached drain thread that waits for the last
//! clone to drop, at which point `Router`'s `Drop` runs the PR-3 drain
//! (every accepted request answered, threads joined).  No request is
//! ever dropped or answered by the wrong generation — the property
//! `tests/lifecycle.rs` hammers.
//!
//! **Generations.**  A global epoch counter stamps every (re)read of a
//! model's weights from disk.  Lazy resident builds and LRU
//! evict/rebuild cycles reuse the already-mapped weights, so they do
//! NOT bump the generation: same weights, same logits, same epoch.
//!
//! **Cold models are cheap.**  Mounting with `lazy = true` maps the
//! BKW file ([`WeightFile::open_mmap`] — address space, not resident
//! heap) and records the shape contract, deferring Plan compilation
//! and replica spawn to the first request.  With
//! [`RegistryConfig::max_resident`] set, the registry LRU-demotes
//! resident models back to this cold state, so a node can keep far
//! more mounted models than it has memory for compiled pipelines —
//! the deployment-density payoff of 1-bit weights.
//!
//! Lock order (must never be reversed): `models` map → per-model
//! `slot` → `lru` list.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::bitops::XnorImpl;
use crate::coordinator::{
    Backend, Metrics, NativeBackend, Router, RouterConfig,
};
use crate::model::{BnnEngine, EngineKernel, WeightFile};

/// Lifecycle state of one mounted model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelState {
    /// A build is in flight: initial mount, reload, or a lazy model
    /// compiling on first request.  During a *reload* the previous
    /// router keeps serving.
    Loading,
    /// Serving (or, for a cold lazy model, ready to build on demand).
    Ready,
    /// Unmounted; the old pipeline is draining and the name is gone
    /// from the map.
    Draining,
    /// The (initial or only) build failed; requests get the stored
    /// error until the model is unmounted or successfully reloaded.
    Failed,
}

impl ModelState {
    /// Wire label used by the admin API (`loading | ready | draining |
    /// failed`).
    pub fn as_str(self) -> &'static str {
        match self {
            ModelState::Loading => "loading",
            ModelState::Ready => "ready",
            ModelState::Draining => "draining",
            ModelState::Failed => "failed",
        }
    }
}

/// How the registry builds pipelines for mounted models.
#[derive(Debug, Clone, Copy)]
pub struct RegistryConfig {
    /// Kernel arm every mounted model compiles against.
    pub kernel: EngineKernel,
    /// Max batch per compiled plan.
    pub max_batch: usize,
    /// Router sizing (queue, replicas, batch policy) per model.
    pub router: RouterConfig,
    /// Upper bound on models with a *resident* (compiled) pipeline;
    /// beyond it the least-recently-used resident model is demoted to
    /// cold (weights stay mapped, router drains).  `0` = unlimited.
    pub max_resident: usize,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            kernel: EngineKernel::Xnor(XnorImpl::Auto),
            max_batch: 8,
            router: RouterConfig::default(),
            max_resident: 0,
        }
    }
}

/// Typed registry failures; the HTTP layer maps each to a status code.
#[derive(Debug, thiserror::Error)]
pub enum RegistryError {
    /// Mounting a name that is already mounted (unmount or reload it).
    #[error("model '{0}' is already mounted")]
    AlreadyMounted(String),
    /// The name is not mounted.
    #[error("unknown model '{0}'")]
    NotFound(String),
    /// Reloading a model that was registered without a weight path
    /// (e.g. a pre-built router handed to [`ModelRegistry::insert_router`]).
    #[error("model '{0}' has no weight path to reload from")]
    NotReloadable(String),
    /// A mount/reload build for this model is already in flight.
    #[error("model '{0}' is already loading")]
    ReloadInProgress(String),
    /// The model's build failed; the stored error explains why.
    #[error("model '{name}' failed to load: {error}")]
    Failed {
        /// The model.
        name: String,
        /// The stored build error.
        error: String,
    },
    /// A build did not settle within the wait bound.
    #[error("timed out waiting for model '{0}' to load")]
    LoadTimeout(String),
    /// A model name outside `[A-Za-z0-9._-]+`.
    #[error("bad model name '{0}' (use letters, digits, '.', '_', '-')")]
    BadName(String),
}

/// The shape contract a mounted model serves (known from the weight
/// file even before a pipeline is built).
#[derive(Debug, Clone)]
pub struct ModelContract {
    /// Per-image input shape (C, H, W).
    pub input_shape: (usize, usize, usize),
    /// Number of output classes.
    pub classes: usize,
    /// Class-label table, when the weight file carries one.
    pub labels: Option<Vec<String>>,
    /// Backend label (e.g. `native/xnor/auto`).
    pub backend: String,
    /// Quantization scheme name (`sign_sign`, `xnor_alpha`, ...), when
    /// known from the weight file (hand-registered routers carry
    /// none).
    pub scheme: Option<String>,
}

impl ModelContract {
    /// Bytes one raw image body must carry (`C * H * W`).
    pub fn image_bytes(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }
}

/// A point-in-time view of one mounted model, for `GET /models`.
#[derive(Debug, Clone)]
pub struct ModelStatus {
    /// Mount name.
    pub name: String,
    /// Lifecycle state.
    pub state: ModelState,
    /// The most recent build error, if any (a `failed` model's cause,
    /// or — state `ready` — a reload that failed and was rolled back).
    pub error: Option<String>,
    /// Weight generation: bumped each time the weights are (re)read
    /// from disk, 0 while the first load is still in flight.
    pub generation: u64,
    /// Whether a compiled pipeline is live (false: cold/lazy model).
    pub resident: bool,
    /// Whether the model has a weight path to reload from.
    pub reloadable: bool,
    /// Whether the model's circuit is open: every replica of the live
    /// router is mid-respawn, so classify traffic is being shed with
    /// `503` until at least one replica recovers.  Always false for a
    /// cold model (no live router).
    pub circuit_open: bool,
    /// The shape contract, once known.
    pub contract: Option<ModelContract>,
}

/// Mutable lifecycle state of one model (behind [`ModelEntry::slot`]).
struct Slot {
    state: ModelState,
    error: Option<String>,
    router: Option<Arc<Router>>,
    weights: Option<Arc<WeightFile>>,
    generation: u64,
    contract: Option<ModelContract>,
}

/// One mounted model: immutable identity plus the locked [`Slot`].
pub struct ModelEntry {
    name: String,
    path: Option<PathBuf>,
    slot: Mutex<Slot>,
    cond: Condvar,
}

impl ModelEntry {
    /// The mount name.
    pub fn name(&self) -> &str {
        &self.name
    }

    fn status_of(&self, slot: &Slot) -> ModelStatus {
        ModelStatus {
            name: self.name.clone(),
            state: slot.state,
            error: slot.error.clone(),
            generation: slot.generation,
            resident: slot.router.is_some(),
            reloadable: self.path.is_some(),
            circuit_open: slot
                .router
                .as_ref()
                .is_some_and(|r| r.circuit_open()),
            contract: slot.contract.clone(),
        }
    }

    /// Current lifecycle snapshot.
    pub fn status(&self) -> ModelStatus {
        self.status_of(&self.slot.lock().unwrap())
    }

    /// Block until the in-flight build (if any) settles — state leaves
    /// `loading` — or `timeout` passes; returns the snapshot either
    /// way.  After a *reload*, a settled state of `ready` with
    /// `error = Some(..)` means the reload failed and the previous
    /// generation kept serving.
    pub fn wait_settled(&self, timeout: Duration) -> ModelStatus {
        let guard = self.slot.lock().unwrap();
        let (slot, _timed_out) = self
            .cond
            .wait_timeout_while(guard, timeout, |s| {
                s.state == ModelState::Loading
            })
            .unwrap();
        self.status_of(&slot)
    }
}

/// The live model set: mount, reload, unmount, resolve — see the
/// module docs for the lifecycle and locking discipline.
pub struct ModelRegistry {
    cfg: RegistryConfig,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Global weight-read epoch (generation source).
    epoch: AtomicU64,
    /// Resident-model recency, least-recent first.
    lru: Mutex<Vec<String>>,
}

/// How long [`ModelRegistry::router_for`] waits for an in-flight build
/// before giving up with [`RegistryError::LoadTimeout`].
const BUILD_WAIT: Duration = Duration::from_secs(30);

impl ModelRegistry {
    /// An empty registry serving no models.
    pub fn new(cfg: RegistryConfig) -> Arc<Self> {
        Arc::new(Self {
            cfg,
            models: RwLock::new(BTreeMap::new()),
            epoch: AtomicU64::new(0),
            lru: Mutex::new(Vec::new()),
        })
    }

    /// The build configuration.
    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    fn next_generation(&self) -> u64 {
        self.epoch.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn validate_name(name: &str) -> Result<(), RegistryError> {
        let ok = !name.is_empty()
            && name.len() <= 128
            && name.chars().all(|c| {
                c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')
            });
        if ok {
            Ok(())
        } else {
            Err(RegistryError::BadName(name.to_string()))
        }
    }

    /// Register a pre-built router under `name` (immediately `ready`).
    /// Such models have no weight path, so they cannot be reloaded —
    /// this is the bridge for the legacy `serve --backend` path and
    /// for tests that build routers by hand.
    pub fn insert_router(&self, name: &str, router: Router)
                         -> Result<(), RegistryError> {
        Self::validate_name(name)?;
        let mut models = self.models.write().unwrap();
        if models.contains_key(name) {
            return Err(RegistryError::AlreadyMounted(name.to_string()));
        }
        let contract = ModelContract {
            input_shape: router.input_shape(),
            classes: router.classes(),
            labels: router.labels().map(<[String]>::to_vec),
            backend: router.backend_name().to_string(),
            scheme: None,
        };
        models.insert(
            name.to_string(),
            Arc::new(ModelEntry {
                name: name.to_string(),
                path: None,
                slot: Mutex::new(Slot {
                    state: ModelState::Ready,
                    error: None,
                    router: Some(Arc::new(router)),
                    weights: None,
                    generation: self.next_generation(),
                    contract: Some(contract),
                }),
                cond: Condvar::new(),
            }),
        );
        drop(models);
        self.touch_lru(name);
        Ok(())
    }

    /// Mount `name` from a BKW file at `path`.  Registers the entry as
    /// `loading` and returns immediately; the weight read (and, unless
    /// `lazy`, the Plan build and replica spawn) happens on a detached
    /// builder thread so in-flight traffic never blocks.  Callers that
    /// want synchronous semantics follow with
    /// [`ModelEntry::wait_settled`].
    pub fn mount(
        self: &Arc<Self>,
        name: &str,
        path: impl Into<PathBuf>,
        lazy: bool,
    ) -> Result<Arc<ModelEntry>, RegistryError> {
        Self::validate_name(name)?;
        let path = path.into();
        let entry = {
            let mut models = self.models.write().unwrap();
            if models.contains_key(name) {
                return Err(RegistryError::AlreadyMounted(
                    name.to_string(),
                ));
            }
            let entry = Arc::new(ModelEntry {
                name: name.to_string(),
                path: Some(path.clone()),
                slot: Mutex::new(Slot {
                    state: ModelState::Loading,
                    error: None,
                    router: None,
                    weights: None,
                    generation: 0,
                    contract: None,
                }),
                cond: Condvar::new(),
            });
            models.insert(name.to_string(), Arc::clone(&entry));
            entry
        };
        let reg = Arc::clone(self);
        let e = Arc::clone(&entry);
        spawn_named(&format!("bk-mount-{name}"), move || {
            reg.run_initial_build(&e, &path, lazy);
        });
        Ok(entry)
    }

    /// The builder body behind [`ModelRegistry::mount`].
    fn run_initial_build(
        self: &Arc<Self>,
        entry: &Arc<ModelEntry>,
        path: &std::path::Path,
        lazy: bool,
    ) {
        let built = if lazy {
            // Cold mount: map the weights and read the contract off
            // them; no Plan, no replicas, until the first request.
            open_weights(path).and_then(|wf| {
                let spec = wf.net_spec()?;
                let contract = ModelContract {
                    input_shape: spec.input(),
                    classes: spec.classes(),
                    labels: wf.labels().map(<[String]>::to_vec),
                    backend: format!("native/{}", self.cfg.kernel.name()),
                    scheme: Some(spec.scheme().name().to_string()),
                };
                Ok((None, Arc::new(wf), contract))
            })
        } else {
            self.build_pipeline(path, None)
                .map(|(r, wf, c)| (Some(r), wf, c))
        };
        let mut slot = entry.slot.lock().unwrap();
        match built {
            Ok((router, weights, contract)) => {
                let resident = router.is_some();
                slot.router = router;
                slot.weights = Some(weights);
                slot.contract = Some(contract);
                slot.generation = self.next_generation();
                slot.state = ModelState::Ready;
                slot.error = None;
                entry.cond.notify_all();
                drop(slot);
                if resident {
                    self.touch_lru(&entry.name);
                    self.evict_lru(&entry.name);
                }
            }
            Err(e) => {
                slot.state = ModelState::Failed;
                slot.error = Some(format!("{e:#}"));
                entry.cond.notify_all();
            }
        }
    }

    /// Reload `name` from its weight path: build the new generation
    /// off-thread while the current router keeps serving, then
    /// atomically swap and retire the old pipeline (drained by its
    /// last `Arc` reference — zero dropped requests).  On a failed
    /// build the previous generation keeps serving and the error is
    /// stored on the entry.  Returns the entry for
    /// [`ModelEntry::wait_settled`].
    pub fn reload(
        self: &Arc<Self>,
        name: &str,
    ) -> Result<Arc<ModelEntry>, RegistryError> {
        let entry = self.entry(name)?;
        let Some(path) = entry.path.clone() else {
            return Err(RegistryError::NotReloadable(name.to_string()));
        };
        {
            let mut slot = entry.slot.lock().unwrap();
            if slot.state == ModelState::Loading {
                return Err(RegistryError::ReloadInProgress(
                    name.to_string(),
                ));
            }
            if slot.state == ModelState::Draining {
                return Err(RegistryError::NotFound(name.to_string()));
            }
            slot.state = ModelState::Loading;
            slot.error = None;
        }
        let reg = Arc::clone(self);
        let e = Arc::clone(&entry);
        spawn_named(&format!("bk-reload-{name}"), move || {
            // Always re-read from disk: a reload IS a new generation.
            let built = reg.build_pipeline(&path, None);
            let mut slot = e.slot.lock().unwrap();
            match built {
                Ok((router, weights, contract)) => {
                    let old = slot.router.replace(router);
                    slot.weights = Some(weights);
                    slot.contract = Some(contract);
                    slot.generation = reg.next_generation();
                    slot.state = ModelState::Ready;
                    e.cond.notify_all();
                    drop(slot);
                    if let Some(old) = old {
                        retire(old);
                    }
                    reg.touch_lru(&e.name);
                    reg.evict_lru(&e.name);
                }
                Err(err) => {
                    // Roll back: the old generation (if any) keeps
                    // serving; only a model with no live router is
                    // `failed`.
                    slot.state = if slot.router.is_some() {
                        ModelState::Ready
                    } else {
                        ModelState::Failed
                    };
                    slot.error = Some(format!("{err:#}"));
                    e.cond.notify_all();
                }
            }
        });
        Ok(entry)
    }

    /// Unmount `name`: remove it from the map (new lookups 404
    /// immediately), mark it `draining`, and retire its pipeline.
    /// Requests already holding the router finish normally.
    pub fn unmount(&self, name: &str) -> Result<(), RegistryError> {
        let entry = {
            let mut models = self.models.write().unwrap();
            models
                .remove(name)
                .ok_or_else(|| RegistryError::NotFound(name.to_string()))?
        };
        let old = {
            let mut slot = entry.slot.lock().unwrap();
            slot.state = ModelState::Draining;
            slot.weights = None;
            entry.cond.notify_all();
            slot.router.take()
        };
        if let Some(old) = old {
            retire(old);
        }
        self.lru.lock().unwrap().retain(|n| n != name);
        Ok(())
    }

    /// Resolve `name` to its live pipeline and weight generation,
    /// building a cold (lazy or LRU-demoted) model's pipeline on the
    /// spot.  Blocks up to [`BUILD_WAIT`] behind an in-flight initial
    /// build; a reload never blocks resolution, because the old router
    /// stays published until the swap.
    pub fn router_for(
        self: &Arc<Self>,
        name: &str,
    ) -> Result<(Arc<Router>, u64), RegistryError> {
        let entry = self.entry(name)?;
        let mut slot = entry.slot.lock().unwrap();
        loop {
            // A live router serves regardless of a concurrent reload.
            if let Some(router) = &slot.router {
                let out = (Arc::clone(router), slot.generation);
                drop(slot);
                self.touch_lru(name);
                return Ok(out);
            }
            match slot.state {
                ModelState::Draining => {
                    return Err(RegistryError::NotFound(name.to_string()))
                }
                ModelState::Failed => {
                    return Err(RegistryError::Failed {
                        name: name.to_string(),
                        error: slot
                            .error
                            .clone()
                            .unwrap_or_else(|| "unknown error".into()),
                    })
                }
                ModelState::Loading => {
                    let (guard, res) = entry
                        .cond
                        .wait_timeout(slot, BUILD_WAIT)
                        .unwrap();
                    slot = guard;
                    if res.timed_out() && slot.router.is_none() {
                        return Err(RegistryError::LoadTimeout(
                            name.to_string(),
                        ));
                    }
                }
                ModelState::Ready => {
                    // Cold model: build the pipeline here, under a
                    // `loading` guard so concurrent requests wait on
                    // the condvar instead of duplicating the build.
                    let Some(weights) = slot.weights.clone() else {
                        return Err(RegistryError::Failed {
                            name: name.to_string(),
                            error: "no pipeline and no weights".into(),
                        });
                    };
                    slot.state = ModelState::Loading;
                    drop(slot);
                    // Same weights, same logits: the generation does
                    // NOT change on a resident (re)build.
                    let built =
                        self.build_pipeline(std::path::Path::new(""),
                                            Some(weights));
                    slot = entry.slot.lock().unwrap();
                    match built {
                        Ok((router, weights, contract)) => {
                            slot.router = Some(router);
                            slot.weights = Some(weights);
                            slot.contract = Some(contract);
                            slot.state = ModelState::Ready;
                            entry.cond.notify_all();
                            drop(slot);
                            self.evict_lru(name);
                            slot = entry.slot.lock().unwrap();
                        }
                        Err(e) => {
                            slot.state = ModelState::Failed;
                            slot.error = Some(format!("{e:#}"));
                            entry.cond.notify_all();
                        }
                    }
                }
            }
        }
    }

    /// Snapshot every mounted model, sorted by name.
    pub fn list(&self) -> Vec<ModelStatus> {
        self.models
            .read()
            .unwrap()
            .values()
            .map(|e| e.status())
            .collect()
    }

    /// The status of one model.
    pub fn status(&self, name: &str)
                  -> Result<ModelStatus, RegistryError> {
        Ok(self.entry(name)?.status())
    }

    /// Number of mounted models.
    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    /// Whether no models are mounted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Prometheus exposition for the whole registry: the
    /// `bitkernel_models_mounted` gauge, a per-model
    /// `bitkernel_mount_epoch` counter, and every *live* router's
    /// series labelled `model="<name>"`.  Series for unmounted models
    /// vanish with their entries — metrics GC by construction, no
    /// stale labels.
    pub fn render_prometheus(&self) -> String {
        let models = self.models.read().unwrap();
        let mut out = Metrics::render_series(
            "bitkernel_models_mounted",
            "",
            models.len() as u64,
        );
        for (name, entry) in models.iter() {
            let label = format!("model=\"{name}\"");
            let (generation, router) = {
                let slot = entry.slot.lock().unwrap();
                (slot.generation, slot.router.clone())
            };
            out.push_str(&Metrics::render_series(
                "bitkernel_mount_epoch",
                &label,
                generation,
            ));
            if let Some(router) = router {
                out.push_str(
                    &router.metrics().render_prometheus_labeled(&label),
                );
            }
        }
        out
    }

    fn entry(&self, name: &str) -> Result<Arc<ModelEntry>, RegistryError> {
        self.models
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))
    }

    /// Move `name` to the most-recent end of the LRU list.
    fn touch_lru(&self, name: &str) {
        let mut lru = self.lru.lock().unwrap();
        lru.retain(|n| n != name);
        lru.push(name.to_string());
    }

    /// Demote least-recently-used resident models to cold until the
    /// resident count fits [`RegistryConfig::max_resident`], never
    /// touching `keep` (the model just built).  Demotion drops the
    /// compiled pipeline (retired through the usual drain) but keeps
    /// the mapped weights and contract: the model stays `ready` and
    /// rebuilds on its next request at the SAME generation.
    fn evict_lru(&self, keep: &str) {
        if self.cfg.max_resident == 0 {
            return;
        }
        loop {
            let entries: Vec<Arc<ModelEntry>> = {
                let models = self.models.read().unwrap();
                models.values().cloned().collect()
            };
            let resident = entries
                .iter()
                .filter(|e| e.slot.lock().unwrap().router.is_some())
                .count();
            if resident <= self.cfg.max_resident {
                return;
            }
            let order = self.lru.lock().unwrap().clone();
            let victim = order.iter().find_map(|name| {
                if name == keep {
                    return None;
                }
                let entry = entries.iter().find(|e| &e.name == name)?;
                let slot = entry.slot.lock().unwrap();
                (slot.state == ModelState::Ready
                    && slot.router.is_some()
                    && slot.weights.is_some())
                .then(|| Arc::clone(entry))
            });
            let Some(entry) = victim else { return };
            let old = {
                let mut slot = entry.slot.lock().unwrap();
                // Re-check under the lock: a racing request may have
                // touched it, but demotion stays correct either way
                // (the model rebuilds on demand).
                if slot.state != ModelState::Ready {
                    continue;
                }
                slot.router.take()
            };
            self.lru.lock().unwrap().retain(|n| n != entry.name());
            if let Some(old) = old {
                retire(old);
            }
        }
    }

    /// Read weights (unless already mapped), compile a Plan, and spin
    /// up a replica pool — the one build path mount, reload, and lazy
    /// resolution all share.
    fn build_pipeline(
        &self,
        path: &std::path::Path,
        weights: Option<Arc<WeightFile>>,
    ) -> anyhow::Result<(Arc<Router>, Arc<WeightFile>, ModelContract)> {
        let weights = match weights {
            Some(w) => w,
            None => Arc::new(open_weights(path)?),
        };
        let engine = BnnEngine::from_weight_file(&weights)?;
        let plan = engine.plan(self.cfg.kernel, self.cfg.max_batch)?;
        let scheme = Some(plan.scheme().name().to_string());
        let router = Router::start(
            move |_replica| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            self.cfg.router,
        )?;
        let contract = ModelContract {
            input_shape: router.input_shape(),
            classes: router.classes(),
            labels: router.labels().map(<[String]>::to_vec),
            backend: router.backend_name().to_string(),
            scheme,
        };
        Ok((Arc::new(router), weights, contract))
    }
}

/// [`WeightFile::open_mmap`] behind the fault-injection hook: the
/// registry's single choke point for weight reads, so a chaos
/// [`FaultPlan`](crate::testing::chaos::FaultPlan) with
/// `fail_weight_reads` makes mount / reload / lazy-build paths fail in
/// a controlled, typed way.
fn open_weights(path: &std::path::Path) -> anyhow::Result<WeightFile> {
    anyhow::ensure!(
        !crate::testing::chaos::weight_read_fault(),
        "chaos: injected weight-read failure for {}",
        path.display()
    );
    WeightFile::open_mmap(path)
}

/// Park a retired router on a detached drain thread: wait until every
/// in-flight request has dropped its clone, then drop the last
/// reference so `Router`'s `Drop` runs the lossless PR-3 drain.
/// Handler threads never pay the join.
fn retire(router: Arc<Router>) {
    spawn_named("bk-drain", move || {
        while Arc::strong_count(&router) > 1 {
            std::thread::sleep(Duration::from_micros(200));
        }
        drop(router);
    });
}

/// Detached `thread::Builder::spawn` with a name.  A refused spawn
/// (thread exhaustion) is swallowed: a lost builder settles through
/// `router_for`'s load timeout, and a lost drain thread merely delays
/// a retired router's join — neither can drop an accepted request.
fn spawn_named(name: &str, f: impl FnOnce() + Send + 'static) {
    let _ = std::thread::Builder::new().name(name.to_string()).spawn(f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, MockBackend};
    use crate::testing::synthetic_weight_file;
    use crate::model::NetSpec;

    fn test_cfg() -> RegistryConfig {
        RegistryConfig {
            max_batch: 4,
            router: RouterConfig {
                queue_cap: 32,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
            ..RegistryConfig::default()
        }
    }

    fn write_model(dir: &std::path::Path, file: &str, seed: u64)
                   -> std::path::PathBuf {
        let spec = NetSpec::builder((1, 4, 4))
            .conv(2, 3)
            .linear(3)
            .build()
            .unwrap();
        let wf = synthetic_weight_file(&spec, seed);
        let path = dir.join(file);
        wf.save(&path).unwrap();
        path
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bk-reg-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn mount_resolve_reload_unmount() {
        let dir = temp_dir("cycle");
        let path = write_model(&dir, "m.bkw", 3);
        let reg = ModelRegistry::new(test_cfg());

        let entry = reg.mount("m", &path, false).unwrap();
        let st = entry.wait_settled(Duration::from_secs(30));
        assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);
        assert!(st.resident);
        assert!(st.reloadable);
        let gen1 = st.generation;
        assert!(gen1 > 0);

        let (router, gen) = reg.router_for("m").unwrap();
        assert_eq!(gen, gen1);
        let reply =
            router.submit_wait(vec![0.5; router.image_elems()]).unwrap();
        assert_eq!(reply.logits.len(), 3);
        drop(router);

        let entry = reg.reload("m").unwrap();
        let st = entry.wait_settled(Duration::from_secs(30));
        assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);
        assert!(st.error.is_none());
        assert!(st.generation > gen1);

        reg.unmount("m").unwrap();
        assert!(matches!(reg.router_for("m"),
                         Err(RegistryError::NotFound(_))));
        assert!(reg.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_mount_builds_on_first_request_same_generation() {
        let dir = temp_dir("lazy");
        let path = write_model(&dir, "m.bkw", 5);
        let reg = ModelRegistry::new(test_cfg());
        let entry = reg.mount("m", &path, true).unwrap();
        let st = entry.wait_settled(Duration::from_secs(30));
        assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);
        assert!(!st.resident, "lazy mount must stay cold");
        let contract = st.contract.expect("contract known while cold");
        assert_eq!(contract.input_shape, (1, 4, 4));
        assert_eq!(contract.classes, 3);

        let (router, gen) = reg.router_for("m").unwrap();
        assert_eq!(gen, st.generation,
                   "resident build must not bump the generation");
        assert_eq!(router.image_elems(), 16);
        assert!(reg.status("m").unwrap().resident);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_mount_reports_error_and_404s_nothing() {
        let reg = ModelRegistry::new(test_cfg());
        let entry = reg.mount("bad", "/no/such/file.bkw", false).unwrap();
        let st = entry.wait_settled(Duration::from_secs(30));
        assert_eq!(st.state, ModelState::Failed);
        assert!(st.error.is_some());
        assert!(matches!(reg.router_for("bad"),
                         Err(RegistryError::Failed { .. })));
        // A failed model is still mounted (visible, unmountable).
        assert_eq!(reg.len(), 1);
        reg.unmount("bad").unwrap();
    }

    #[test]
    fn duplicate_names_and_bad_names_are_typed_errors() {
        let reg = ModelRegistry::new(test_cfg());
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            test_cfg().router,
        )
        .unwrap();
        reg.insert_router("m", router).unwrap();
        assert!(matches!(reg.mount("m", "/x.bkw", false),
                         Err(RegistryError::AlreadyMounted(_))));
        assert!(matches!(reg.mount("bad name!", "/x.bkw", false),
                         Err(RegistryError::BadName(_))));
        assert!(matches!(reg.reload("m"),
                         Err(RegistryError::NotReloadable(_))));
        assert!(matches!(reg.unmount("ghost"),
                         Err(RegistryError::NotFound(_))));
    }

    #[test]
    fn lru_demotes_but_keeps_models_servable() {
        let dir = temp_dir("lru");
        let pa = write_model(&dir, "a.bkw", 7);
        let pb = write_model(&dir, "b.bkw", 8);
        let mut cfg = test_cfg();
        cfg.max_resident = 1;
        let reg = ModelRegistry::new(cfg);
        for (n, p) in [("a", &pa), ("b", &pb)] {
            let e = reg.mount(n, p, false).unwrap();
            assert_eq!(e.wait_settled(Duration::from_secs(30)).state,
                       ModelState::Ready);
        }
        // Mounting b evicts a (the only other resident model); the
        // eviction runs on b's builder thread just after the ready
        // notify, so poll briefly.
        let settle = std::time::Instant::now();
        while reg.status("a").unwrap().resident
            && settle.elapsed() < Duration::from_secs(10)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!reg.status("a").unwrap().resident);
        assert!(reg.status("b").unwrap().resident);
        // a still serves — it rebuilds on demand at the same generation.
        let gen_a = reg.status("a").unwrap().generation;
        let (router, gen) = reg.router_for("a").unwrap();
        assert_eq!(gen, gen_a);
        let reply =
            router.submit_wait(vec![0.1; router.image_elems()]).unwrap();
        assert_eq!(reply.logits.len(), 3);
        drop(router);
        // ... and now b is the demoted one.
        let settle = std::time::Instant::now();
        while reg.status("b").unwrap().resident
            && settle.elapsed() < Duration::from_secs(10)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!reg.status("b").unwrap().resident);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prometheus_series_vanish_on_unmount() {
        let reg = ModelRegistry::new(test_cfg());
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            test_cfg().router,
        )
        .unwrap();
        reg.insert_router("gone-soon", router).unwrap();
        let text = reg.render_prometheus();
        assert!(text.contains("bitkernel_models_mounted 1"), "{text}");
        assert!(text.contains("model=\"gone-soon\""), "{text}");
        reg.unmount("gone-soon").unwrap();
        let text = reg.render_prometheus();
        assert!(text.contains("bitkernel_models_mounted 0"), "{text}");
        assert!(!text.contains("gone-soon"),
                "stale series must be GC'd: {text}");
    }
}
