//! HTTP/1.1 message parsing and serialization (request side minimal,
//! enough for the coordinator's API surface).
//!
//! The parser is **bounded**: request/header lines are capped at
//! [`MAX_HEADER_LINE`] bytes, a request at [`MAX_HEADERS`] headers and
//! [`MAX_BODY`] body bytes, so a hostile peer streaming an endless
//! header line cannot grow an unbounded buffer.  Framing the server
//! does not speak (`Transfer-Encoding`) is rejected BEFORE any body
//! bytes are read — and both front ends close (never reuse) a
//! connection after any parse error, so unconsumed framing can't
//! poison the next request.
//!
//! Two entry points share the same grammar and bounds:
//!
//! * [`HttpRequest::read`] — pull parsing from a blocking
//!   `BufReader` (the thread-per-connection front end),
//! * [`HttpHead::parse`] — push parsing over whatever bytes have
//!   arrived so far (the epoll front end, which owns many connections
//!   per thread and must never block on a slow peer).  It returns
//!   `Ok(None)` for an incomplete head, so a reactor can retry on the
//!   next readiness event without re-scanning state.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Longest accepted request/header line, in bytes (CRLF included).
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 100;
/// Largest accepted request body, in bytes.
pub const MAX_BODY: usize = 16 << 20;

/// Parse a request line (`GET /p?q HTTP/1.1`, already newline-trimmed)
/// into its components — shared by the blocking and incremental
/// parsers so the two front ends accept exactly the same grammar.
#[allow(clippy::type_complexity)]
fn parse_request_line(
    line: &str,
) -> Result<(String, String, BTreeMap<String, String>, String)> {
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or("").to_uppercase();
    let target = parts.next().context("missing request target")?;
    let version = parts.next().unwrap_or("").to_string();
    ensure!(version.starts_with("HTTP/1."), "bad version '{version}'");
    ensure!(!method.is_empty(), "empty method");

    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.to_string(), ""),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(k.to_string(), v.to_string());
    }
    Ok((method, path, query, version))
}

/// Parse one `Name: value` header line (newline-trimmed, non-empty)
/// into `headers`, enforcing the [`MAX_HEADERS`] cap.
fn parse_header_line(
    line: &str,
    headers: &mut BTreeMap<String, String>,
) -> Result<()> {
    ensure!(headers.len() < MAX_HEADERS, "more than {MAX_HEADERS} headers");
    let (k, v) = line.split_once(':').context("bad header line")?;
    headers.insert(k.trim().to_lowercase(), v.trim().to_string());
    Ok(())
}

/// Keep-alive decision shared by [`HttpRequest`] and [`HttpHead`]: an
/// explicit `Connection: close`/`keep-alive` header wins; otherwise
/// the protocol default applies — keep-alive for HTTP/1.1, close for
/// HTTP/1.0.
fn keep_alive_for(headers: &BTreeMap<String, String>, version: &str) -> bool {
    match headers.get("connection") {
        Some(v) if v.eq_ignore_ascii_case("close") => false,
        Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
        _ => version != "HTTP/1.0",
    }
}

/// `read_line` with a hard byte cap.  Returns `Ok(None)` on EOF before
/// any byte, an error when the line exceeds `max` bytes.
fn read_line_bounded(
    reader: &mut BufReader<impl Read>,
    max: usize,
) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(max as u64 + 1)
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    ensure!(buf.len() <= max, "header line over {max} bytes");
    let line = String::from_utf8(buf).context("non-utf8 header line")?;
    Ok(Some(line))
}

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    /// Uppercased request method.
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Headers with lowercased names.
    pub headers: BTreeMap<String, String>,
    /// Raw request body.
    pub body: Vec<u8>,
    /// Protocol version from the request line (`HTTP/1.0` or
    /// `HTTP/1.1`) — decides the keep-alive default.
    pub version: String,
}

impl HttpRequest {
    /// Read one request from a buffered stream.  Returns Ok(None) on a
    /// cleanly closed connection (EOF before any bytes).
    pub fn read(reader: &mut BufReader<impl Read>) -> Result<Option<Self>> {
        let Some(line) = read_line_bounded(reader, MAX_HEADER_LINE)?
        else {
            return Ok(None);
        };
        let (method, path, query, version) =
            parse_request_line(line.trim_end())?;

        let mut headers = BTreeMap::new();
        loop {
            let h = read_line_bounded(reader, MAX_HEADER_LINE)?
                .context("eof in headers")?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            parse_header_line(h, &mut headers)?;
        }

        let head = HttpHead { method, path, query, headers, version };
        // Framing we don't speak is rejected BEFORE touching the body:
        // reading a content-length body off a chunked request would
        // leave the chunk framing on the stream and poison keep-alive
        // reuse for whatever the connection handler does next.
        let len = head.body_len()?;
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("reading body")?;
        Ok(Some(head.into_request(body)))
    }

    /// Whether the client wants the connection kept open.  An explicit
    /// `Connection: close`/`keep-alive` header wins; otherwise the
    /// protocol default applies — keep-alive for HTTP/1.1, close for
    /// HTTP/1.0.
    pub fn wants_keep_alive(&self) -> bool {
        keep_alive_for(&self.headers, &self.version)
    }
}

/// A parsed request head (request line + headers) whose body has not
/// been read yet — the incremental-parse form used by the event-loop
/// front end, which receives bytes in arbitrary chunks and must not
/// block waiting for the rest of a message.
#[derive(Debug, Clone)]
pub struct HttpHead {
    /// Uppercased request method.
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Headers with lowercased names.
    pub headers: BTreeMap<String, String>,
    /// Protocol version from the request line.
    pub version: String,
}

/// Scan the next newline-terminated line out of `buf[*pos..]`,
/// advancing `pos` past it.  `Ok(None)` when the buffer holds no
/// complete line yet; an error once the (partial) line already exceeds
/// the [`MAX_HEADER_LINE`] cap, so a trickling peer cannot grow the
/// buffer without bound.
fn next_line<'a>(buf: &'a [u8], pos: &mut usize) -> Result<Option<&'a str>> {
    let rest = &buf[*pos..];
    match rest.iter().position(|&b| b == b'\n') {
        Some(i) => {
            ensure!(
                i + 1 <= MAX_HEADER_LINE,
                "header line over {MAX_HEADER_LINE} bytes"
            );
            let line = std::str::from_utf8(&rest[..i])
                .context("non-utf8 header line")?;
            *pos += i + 1;
            Ok(Some(line.trim_end()))
        }
        None => {
            ensure!(
                rest.len() < MAX_HEADER_LINE,
                "header line over {MAX_HEADER_LINE} bytes"
            );
            Ok(None)
        }
    }
}

impl HttpHead {
    /// Try to parse a complete request head out of `buf`.  Returns
    /// `Ok(Some((head, consumed)))` once the blank line ending the
    /// head has arrived (`consumed` = bytes of `buf` the head spans,
    /// so the body starts at `buf[consumed..]`), `Ok(None)` while the
    /// head is still incomplete, and an error for malformed or
    /// over-limit input — same grammar and caps as
    /// [`HttpRequest::read`].
    pub fn parse(buf: &[u8]) -> Result<Option<(Self, usize)>> {
        let mut pos = 0usize;
        let Some(line) = next_line(buf, &mut pos)? else {
            return Ok(None);
        };
        let (method, path, query, version) = parse_request_line(line)?;
        let mut headers = BTreeMap::new();
        loop {
            let Some(line) = next_line(buf, &mut pos)? else {
                return Ok(None);
            };
            if line.is_empty() {
                let head = Self { method, path, query, headers, version };
                return Ok(Some((head, pos)));
            }
            parse_header_line(line, &mut headers)?;
        }
    }

    /// Body length this head advertises, validated: rejects
    /// `Transfer-Encoding` framing (which the server does not speak)
    /// before any body byte is consumed, and bodies over [`MAX_BODY`].
    pub fn body_len(&self) -> Result<usize> {
        if let Some(te) = self.headers.get("transfer-encoding") {
            bail!("transfer-encoding '{te}' not supported");
        }
        let len: usize = self
            .headers
            .get("content-length")
            .map(|v| v.parse().context("bad content-length"))
            .transpose()?
            .unwrap_or(0);
        ensure!(len <= MAX_BODY, "body too large ({len} bytes)");
        Ok(len)
    }

    /// Whether the client wants the connection kept open (same rules
    /// as [`HttpRequest::wants_keep_alive`]).
    pub fn wants_keep_alive(&self) -> bool {
        keep_alive_for(&self.headers, &self.version)
    }

    /// Attach a body, producing the full [`HttpRequest`].
    pub fn into_request(self, body: Vec<u8>) -> HttpRequest {
        HttpRequest {
            method: self.method,
            path: self.path,
            query: self.query,
            headers: self.headers,
            body,
            version: self.version,
        }
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Status code.
    pub status: u16,
    /// Content-Type header value.
    pub content_type: String,
    /// Extra headers (name, value) emitted verbatim after the standard
    /// set — e.g. `Retry-After` on 503/504.
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// A JSON response.
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json".into(),
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            headers: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// Append one extra response header.
    pub fn with_header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Self {
        self.headers.push((name.into(), value.into()));
        self
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Internal Server Error",
        }
    }

    /// Serialize status line, headers, and body to `w`.
    pub fn write(&self, w: &mut impl Write, keep_alive: bool) -> Result<()> {
        w.write_all(&self.to_bytes(keep_alive))?;
        w.flush()?;
        Ok(())
    }

    /// Serialize the full wire form to an owned buffer — what the
    /// non-blocking front end appends to a connection's write buffer
    /// (it cannot use blocking [`HttpResponse::write`]).
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        use std::fmt::Write as _;
        let mut head = String::with_capacity(128);
        let _ = write!(
            head,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.headers {
            let _ = write!(head, "{name}: {value}\r\n");
        }
        head.push_str("\r\n");
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

/// Minimal blocking HTTP/1.1 client call: one request, one response,
/// connection closed.  Returns `(status, body)`.  This is what the
/// `bitkernel mount`/`unmount`/`reload` CLI subcommands and the
/// lifecycle smoke example speak to the admin API with — deliberately
/// tiny (no keep-alive, no chunked bodies, 30 s timeouts) so the CLI
/// needs no client dependency.  For transient-failure tolerance see
/// [`http_call_retry`]; for a caller-chosen timeout see
/// [`http_call_timeout`].
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let timeout = std::time::Duration::from_secs(30);
    http_call_timeout(addr, method, path, body, timeout)
}

/// [`http_call`] with a caller-chosen socket read/write timeout
/// instead of the hardcoded 30 s — test harnesses racing a server's
/// idle-timeout knob need a client bound tighter than the default.
pub fn http_call_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    timeout: std::time::Duration,
) -> Result<(u16, Vec<u8>)> {
    use std::net::TcpStream;

    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status")?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .with_context(|| format!("bad status line '{status_line}'"))?
        .parse()
        .context("bad status code")?;
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        ensure!(reader.read_line(&mut line)? > 0, "eof in headers");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    ensure!(len <= 16 << 20, "response too large ({len} bytes)");
    let mut out = vec![0u8; len];
    reader.read_exact(&mut out).context("reading body")?;
    Ok((status, out))
}

/// Whether an [`http_call`] failure is worth retrying: a transient
/// transport error (server not up yet, connection dropped, timeout) as
/// opposed to a protocol or caller error.
fn retryable(err: &anyhow::Error) -> bool {
    use std::io::ErrorKind;
    err.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                ErrorKind::ConnectionRefused
                    | ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::NotConnected
                    | ErrorKind::TimedOut
                    | ErrorKind::WouldBlock
            )
        })
    })
}

/// [`http_call`] with up to `retries` retries on transient transport
/// errors (connection refused/reset, timeout), sleeping a jittered
/// exponential backoff between attempts (50ms doubling to a 2s cap,
/// jittered to 50–100% so concurrent clients don't retry in
/// lockstep).  Non-transient errors and HTTP error statuses are
/// returned immediately — a `500` is an answer, not a network fault.
pub fn http_call_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    retries: usize,
) -> Result<(u16, Vec<u8>)> {
    use std::time::{Duration, SystemTime, UNIX_EPOCH};

    let seed = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(0)
        | 1;
    let mut rng = crate::utils::Rng::new(seed);
    let mut delay = Duration::from_millis(50);
    let mut attempt = 0;
    loop {
        match http_call(addr, method, path, body) {
            Ok(r) => return Ok(r),
            Err(e) if attempt < retries && retryable(&e) => {
                attempt += 1;
                let jittered =
                    delay.mul_f64(0.5 + 0.5 * rng.next_f32() as f64);
                crate::log_warn!(
                    "{method} {path}: {e:#}; \
                     retry {attempt}/{retries} in {jittered:?}"
                );
                std::thread::sleep(jittered);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &str) -> HttpRequest {
        HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap()
            .unwrap()
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /classify?model=bnn&x=1 HTTP/1.1\r\nHost: a\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/classify");
        assert_eq!(r.query.get("model").map(String::as_str), Some("bnn"));
        assert_eq!(r.query.get("x").map(String::as_str), Some("1"));
        assert_eq!(r.version, "HTTP/1.1");
        assert!(r.wants_keep_alive());
    }

    #[test]
    fn parses_post_body() {
        let r = parse(
            "POST /c HTTP/1.1\r\nContent-Length: 5\r\nConnection: close\r\n\r\nhello",
        );
        assert_eq!(r.body, b"hello");
        assert!(!r.wants_keep_alive());
    }

    #[test]
    fn eof_returns_none() {
        let r = HttpRequest::read(&mut BufReader::new(&b""[..])).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn http10_defaults_to_close() {
        let r = parse("GET / HTTP/1.0\r\nHost: a\r\n\r\n");
        assert_eq!(r.version, "HTTP/1.0");
        assert!(!r.wants_keep_alive(), "1.0 default must be close");
        // An explicit keep-alive opt-in still wins on 1.0...
        let r = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(r.wants_keep_alive());
        // ...and an explicit close on 1.1.
        let r = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!r.wants_keep_alive());
    }

    #[test]
    fn rejects_bad_version_and_huge_body() {
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"GET / SPDY/99\r\n\r\n"[..]
        ))
        .is_err());
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n"[..]
        ))
        .is_err());
    }

    #[test]
    fn rejects_chunked_before_reading_the_body() {
        // The chunked rejection must fire BEFORE the content-length
        // body read: a combined request errors on transfer-encoding,
        // not on body framing.
        let raw = "POST /c HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\
                   Content-Length: 5\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("transfer-encoding"),
            "{err:#}"
        );
        // Casing and variants are rejected too.
        let raw = "POST /c HTTP/1.1\r\nTransfer-Encoding: GZIP\r\n\r\n";
        assert!(HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .is_err());
    }

    #[test]
    fn bounds_header_line_length() {
        let raw = format!(
            "GET / HTTP/1.1\r\nx-big: {}\r\n\r\n",
            "a".repeat(MAX_HEADER_LINE + 10)
        );
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("header line over"), "{err:#}");
        // An endless REQUEST line (no newline at all) is bounded too.
        let raw = "G".repeat(MAX_HEADER_LINE * 4);
        assert!(HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .is_err());
    }

    #[test]
    fn bounds_header_count() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 5) {
            raw.push_str(&format!("x-h{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("headers"), "{err:#}");
    }

    #[test]
    fn rejects_bad_content_length_and_eof_mid_body() {
        let raw = "POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("content-length"), "{err:#}");
        // Advertised 10 bytes, stream ends after 3.
        let raw = "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("reading body"), "{err:#}");
    }

    #[test]
    fn response_roundtrip() {
        let resp = HttpResponse::json(200, "{\"ok\":true}".into());
        let mut buf = Vec::new();
        resp.write(&mut buf, true).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn extra_headers_and_504_reason() {
        let resp = HttpResponse::json(503, "{}".into())
            .with_header("Retry-After", "1");
        let mut buf = Vec::new();
        resp.write(&mut buf, false).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\r\nRetry-After: 1\r\n"), "{s}");
        assert!(s.contains("Connection: close"), "{s}");
        assert!(s.ends_with("\r\n\r\n{}"), "{s}");
        let resp = HttpResponse::json(504, "{}".into());
        let mut buf = Vec::new();
        resp.write(&mut buf, false).unwrap();
        assert!(String::from_utf8(buf)
            .unwrap()
            .starts_with("HTTP/1.1 504 Gateway Timeout\r\n"));
    }

    #[test]
    fn head_parse_incremental_matches_blocking() {
        let raw = b"POST /classify?model=bnn HTTP/1.1\r\nHost: a\r\n\
                    Content-Length: 5\r\n\r\nhello";
        // Every prefix short of the blank line is "incomplete", never
        // an error — the reactor keeps the buffer and retries.
        let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        for cut in 0..head_end {
            assert!(
                HttpHead::parse(&raw[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes must be incomplete"
            );
        }
        let (head, consumed) = HttpHead::parse(raw).unwrap().unwrap();
        assert_eq!(consumed, head_end);
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/classify");
        assert_eq!(head.query.get("model").map(String::as_str), Some("bnn"));
        assert_eq!(head.body_len().unwrap(), 5);
        assert!(head.wants_keep_alive());
        let req = head.into_request(raw[consumed..].to_vec());
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn head_parse_enforces_the_same_bounds() {
        // Endless request line with no newline: bounded even before a
        // complete line exists.
        let raw = vec![b'G'; MAX_HEADER_LINE + 1];
        assert!(HttpHead::parse(&raw).is_err());
        // Header-count cap.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 5) {
            raw.push_str(&format!("x-h{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        assert!(HttpHead::parse(raw.as_bytes()).is_err());
        // Transfer-encoding rejected at body_len, bad version at parse.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        let (head, _) = HttpHead::parse(raw).unwrap().unwrap();
        assert!(head.body_len().is_err());
        assert!(HttpHead::parse(b"GET / SPDY/99\r\n\r\n").is_err());
        // Oversized advertised body.
        let raw = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        );
        let (head, _) = HttpHead::parse(raw.as_bytes()).unwrap().unwrap();
        assert!(head.body_len().is_err());
    }

    #[test]
    fn head_parse_pipelined_requests_consume_exactly_one() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let (head, consumed) = HttpHead::parse(raw).unwrap().unwrap();
        assert_eq!(head.path, "/a");
        let (head2, consumed2) =
            HttpHead::parse(&raw[consumed..]).unwrap().unwrap();
        assert_eq!(head2.path, "/b");
        assert_eq!(consumed + consumed2, raw.len());
    }

    #[test]
    fn retry_reaches_a_delayed_start_server() {
        use std::net::TcpListener;
        // Reserve a free port, release it, and only bind the server
        // there after a delay — the first attempts see
        // ConnectionRefused and must be retried to succeed.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(200));
            let listener = TcpListener::bind(&addr2).unwrap();
            let (mut s, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let req = HttpRequest::read(&mut reader).unwrap().unwrap();
            assert_eq!(req.method, "GET");
            HttpResponse::text(200, "late but here")
                .write(&mut s, false)
                .unwrap();
        });
        let (status, body) =
            http_call_retry(&addr, "GET", "/x", b"", 8).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"late but here");
        server.join().unwrap();
    }

    #[test]
    fn zero_retries_fails_fast_on_refused() {
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = std::time::Instant::now();
        assert!(http_call_retry(&addr, "GET", "/", b"", 0).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }
}
