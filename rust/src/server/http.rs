//! HTTP/1.1 message parsing and serialization (request side minimal,
//! enough for the coordinator's API surface).
//!
//! The parser is **bounded**: request/header lines are capped at
//! [`MAX_HEADER_LINE`] bytes and a request at [`MAX_HEADERS`] headers,
//! so a hostile peer streaming an endless header line cannot grow an
//! unbounded buffer.  Framing the server does not speak
//! (`Transfer-Encoding`) is rejected BEFORE any body bytes are read —
//! and the serve loop closes (never reuses) a connection after any
//! parse error, so unconsumed framing can't poison the next request.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Longest accepted request/header line, in bytes (CRLF included).
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 100;

/// `read_line` with a hard byte cap.  Returns `Ok(None)` on EOF before
/// any byte, an error when the line exceeds `max` bytes.
fn read_line_bounded(
    reader: &mut BufReader<impl Read>,
    max: usize,
) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(max as u64 + 1)
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    ensure!(buf.len() <= max, "header line over {max} bytes");
    let line = String::from_utf8(buf).context("non-utf8 header line")?;
    Ok(Some(line))
}

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    /// Uppercased request method.
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Headers with lowercased names.
    pub headers: BTreeMap<String, String>,
    /// Raw request body.
    pub body: Vec<u8>,
    /// Protocol version from the request line (`HTTP/1.0` or
    /// `HTTP/1.1`) — decides the keep-alive default.
    pub version: String,
}

impl HttpRequest {
    /// Read one request from a buffered stream.  Returns Ok(None) on a
    /// cleanly closed connection (EOF before any bytes).
    pub fn read(reader: &mut BufReader<impl Read>) -> Result<Option<Self>> {
        let Some(line) = read_line_bounded(reader, MAX_HEADER_LINE)?
        else {
            return Ok(None);
        };
        let mut parts = line.trim_end().split(' ');
        let method = parts.next().unwrap_or("").to_uppercase();
        let target = parts.next().context("missing request target")?;
        let version = parts.next().unwrap_or("").to_string();
        ensure!(version.starts_with("HTTP/1."), "bad version '{version}'");
        ensure!(!method.is_empty(), "empty method");

        let (path, query_str) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q),
            None => (target.to_string(), ""),
        };
        let mut query = BTreeMap::new();
        for pair in query_str.split('&').filter(|s| !s.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.insert(k.to_string(), v.to_string());
        }

        let mut headers = BTreeMap::new();
        loop {
            let h = read_line_bounded(reader, MAX_HEADER_LINE)?
                .context("eof in headers")?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            ensure!(
                headers.len() < MAX_HEADERS,
                "more than {MAX_HEADERS} headers"
            );
            let (k, v) = h.split_once(':').context("bad header line")?;
            headers.insert(k.trim().to_lowercase(), v.trim().to_string());
        }

        // Framing we don't speak is rejected BEFORE touching the body:
        // reading a content-length body off a chunked request would
        // leave the chunk framing on the stream and poison keep-alive
        // reuse for whatever the connection handler does next.
        if let Some(te) = headers.get("transfer-encoding") {
            bail!("transfer-encoding '{te}' not supported");
        }
        let len: usize = headers
            .get("content-length")
            .map(|v| v.parse().context("bad content-length"))
            .transpose()?
            .unwrap_or(0);
        ensure!(len <= 16 << 20, "body too large ({len} bytes)");
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("reading body")?;
        Ok(Some(Self { method, path, query, headers, body, version }))
    }

    /// Whether the client wants the connection kept open.  An explicit
    /// `Connection: close`/`keep-alive` header wins; otherwise the
    /// protocol default applies — keep-alive for HTTP/1.1, close for
    /// HTTP/1.0.
    pub fn wants_keep_alive(&self) -> bool {
        match self.headers.get("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.version != "HTTP/1.0",
        }
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Status code.
    pub status: u16,
    /// Content-Type header value.
    pub content_type: String,
    /// Extra headers (name, value) emitted verbatim after the standard
    /// set — e.g. `Retry-After` on 503/504.
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// A JSON response.
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json".into(),
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            headers: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// Append one extra response header.
    pub fn with_header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Self {
        self.headers.push((name.into(), value.into()));
        self
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Internal Server Error",
        }
    }

    /// Serialize status line, headers, and body to `w`.
    pub fn write(&self, w: &mut impl Write, keep_alive: bool) -> Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// Minimal blocking HTTP/1.1 client call: one request, one response,
/// connection closed.  Returns `(status, body)`.  This is what the
/// `bitkernel mount`/`unmount`/`reload` CLI subcommands and the
/// lifecycle smoke example speak to the admin API with — deliberately
/// tiny (no keep-alive, no chunked bodies, 30 s timeouts) so the CLI
/// needs no client dependency.  For transient-failure tolerance see
/// [`http_call_retry`].
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    use std::net::TcpStream;
    use std::time::Duration;

    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status")?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .with_context(|| format!("bad status line '{status_line}'"))?
        .parse()
        .context("bad status code")?;
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        ensure!(reader.read_line(&mut line)? > 0, "eof in headers");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    ensure!(len <= 16 << 20, "response too large ({len} bytes)");
    let mut out = vec![0u8; len];
    reader.read_exact(&mut out).context("reading body")?;
    Ok((status, out))
}

/// Whether an [`http_call`] failure is worth retrying: a transient
/// transport error (server not up yet, connection dropped, timeout) as
/// opposed to a protocol or caller error.
fn retryable(err: &anyhow::Error) -> bool {
    use std::io::ErrorKind;
    err.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                ErrorKind::ConnectionRefused
                    | ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::NotConnected
                    | ErrorKind::TimedOut
                    | ErrorKind::WouldBlock
            )
        })
    })
}

/// [`http_call`] with up to `retries` retries on transient transport
/// errors (connection refused/reset, timeout), sleeping a jittered
/// exponential backoff between attempts (50ms doubling to a 2s cap,
/// jittered to 50–100% so concurrent clients don't retry in
/// lockstep).  Non-transient errors and HTTP error statuses are
/// returned immediately — a `500` is an answer, not a network fault.
pub fn http_call_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    retries: usize,
) -> Result<(u16, Vec<u8>)> {
    use std::time::{Duration, SystemTime, UNIX_EPOCH};

    let seed = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(0)
        | 1;
    let mut rng = crate::utils::Rng::new(seed);
    let mut delay = Duration::from_millis(50);
    let mut attempt = 0;
    loop {
        match http_call(addr, method, path, body) {
            Ok(r) => return Ok(r),
            Err(e) if attempt < retries && retryable(&e) => {
                attempt += 1;
                let jittered =
                    delay.mul_f64(0.5 + 0.5 * rng.next_f32() as f64);
                crate::log_warn!(
                    "{method} {path}: {e:#}; \
                     retry {attempt}/{retries} in {jittered:?}"
                );
                std::thread::sleep(jittered);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &str) -> HttpRequest {
        HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap()
            .unwrap()
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /classify?model=bnn&x=1 HTTP/1.1\r\nHost: a\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/classify");
        assert_eq!(r.query.get("model").map(String::as_str), Some("bnn"));
        assert_eq!(r.query.get("x").map(String::as_str), Some("1"));
        assert_eq!(r.version, "HTTP/1.1");
        assert!(r.wants_keep_alive());
    }

    #[test]
    fn parses_post_body() {
        let r = parse(
            "POST /c HTTP/1.1\r\nContent-Length: 5\r\nConnection: close\r\n\r\nhello",
        );
        assert_eq!(r.body, b"hello");
        assert!(!r.wants_keep_alive());
    }

    #[test]
    fn eof_returns_none() {
        let r = HttpRequest::read(&mut BufReader::new(&b""[..])).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn http10_defaults_to_close() {
        let r = parse("GET / HTTP/1.0\r\nHost: a\r\n\r\n");
        assert_eq!(r.version, "HTTP/1.0");
        assert!(!r.wants_keep_alive(), "1.0 default must be close");
        // An explicit keep-alive opt-in still wins on 1.0...
        let r = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(r.wants_keep_alive());
        // ...and an explicit close on 1.1.
        let r = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!r.wants_keep_alive());
    }

    #[test]
    fn rejects_bad_version_and_huge_body() {
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"GET / SPDY/99\r\n\r\n"[..]
        ))
        .is_err());
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n"[..]
        ))
        .is_err());
    }

    #[test]
    fn rejects_chunked_before_reading_the_body() {
        // The chunked rejection must fire BEFORE the content-length
        // body read: a combined request errors on transfer-encoding,
        // not on body framing.
        let raw = "POST /c HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\
                   Content-Length: 5\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("transfer-encoding"),
            "{err:#}"
        );
        // Casing and variants are rejected too.
        let raw = "POST /c HTTP/1.1\r\nTransfer-Encoding: GZIP\r\n\r\n";
        assert!(HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .is_err());
    }

    #[test]
    fn bounds_header_line_length() {
        let raw = format!(
            "GET / HTTP/1.1\r\nx-big: {}\r\n\r\n",
            "a".repeat(MAX_HEADER_LINE + 10)
        );
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("header line over"), "{err:#}");
        // An endless REQUEST line (no newline at all) is bounded too.
        let raw = "G".repeat(MAX_HEADER_LINE * 4);
        assert!(HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .is_err());
    }

    #[test]
    fn bounds_header_count() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 5) {
            raw.push_str(&format!("x-h{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("headers"), "{err:#}");
    }

    #[test]
    fn rejects_bad_content_length_and_eof_mid_body() {
        let raw = "POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("content-length"), "{err:#}");
        // Advertised 10 bytes, stream ends after 3.
        let raw = "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        let err = HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("reading body"), "{err:#}");
    }

    #[test]
    fn response_roundtrip() {
        let resp = HttpResponse::json(200, "{\"ok\":true}".into());
        let mut buf = Vec::new();
        resp.write(&mut buf, true).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn extra_headers_and_504_reason() {
        let resp = HttpResponse::json(503, "{}".into())
            .with_header("Retry-After", "1");
        let mut buf = Vec::new();
        resp.write(&mut buf, false).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\r\nRetry-After: 1\r\n"), "{s}");
        assert!(s.contains("Connection: close"), "{s}");
        assert!(s.ends_with("\r\n\r\n{}"), "{s}");
        let resp = HttpResponse::json(504, "{}".into());
        let mut buf = Vec::new();
        resp.write(&mut buf, false).unwrap();
        assert!(String::from_utf8(buf)
            .unwrap()
            .starts_with("HTTP/1.1 504 Gateway Timeout\r\n"));
    }

    #[test]
    fn retry_reaches_a_delayed_start_server() {
        use std::net::TcpListener;
        // Reserve a free port, release it, and only bind the server
        // there after a delay — the first attempts see
        // ConnectionRefused and must be retried to succeed.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(200));
            let listener = TcpListener::bind(&addr2).unwrap();
            let (mut s, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let req = HttpRequest::read(&mut reader).unwrap().unwrap();
            assert_eq!(req.method, "GET");
            HttpResponse::text(200, "late but here")
                .write(&mut s, false)
                .unwrap();
        });
        let (status, body) =
            http_call_retry(&addr, "GET", "/x", b"", 8).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"late but here");
        server.join().unwrap();
    }

    #[test]
    fn zero_retries_fails_fast_on_refused() {
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = std::time::Instant::now();
        assert!(http_call_retry(&addr, "GET", "/", b"", 0).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }
}
