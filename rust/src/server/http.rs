//! HTTP/1.1 message parsing and serialization (request side minimal,
//! enough for the coordinator's API surface).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    /// Uppercased request method.
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Parsed query parameters.
    pub query: BTreeMap<String, String>,
    /// Headers with lowercased names.
    pub headers: BTreeMap<String, String>,
    /// Raw request body.
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Read one request from a buffered stream.  Returns Ok(None) on a
    /// cleanly closed connection (EOF before any bytes).
    pub fn read(reader: &mut BufReader<impl Read>) -> Result<Option<Self>> {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        let mut parts = line.trim_end().split(' ');
        let method = parts.next().unwrap_or("").to_uppercase();
        let target = parts.next().context("missing request target")?;
        let version = parts.next().unwrap_or("");
        ensure!(version.starts_with("HTTP/1."), "bad version '{version}'");
        ensure!(!method.is_empty(), "empty method");

        let (path, query_str) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q),
            None => (target.to_string(), ""),
        };
        let mut query = BTreeMap::new();
        for pair in query_str.split('&').filter(|s| !s.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.insert(k.to_string(), v.to_string());
        }

        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            ensure!(reader.read_line(&mut h)? > 0, "eof in headers");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h.split_once(':').context("bad header line")?;
            headers.insert(k.trim().to_lowercase(), v.trim().to_string());
        }

        let len: usize = headers
            .get("content-length")
            .map(|v| v.parse().context("bad content-length"))
            .transpose()?
            .unwrap_or(0);
        ensure!(len <= 16 << 20, "body too large ({len} bytes)");
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("reading body")?;
        if headers.get("transfer-encoding").map(|s| s.as_str())
            == Some("chunked")
        {
            bail!("chunked bodies not supported");
        }
        Ok(Some(Self { method, path, query, headers, body }))
    }

    /// Whether the client wants the connection kept open (HTTP/1.1
    /// default unless `Connection: close`).
    pub fn wants_keep_alive(&self) -> bool {
        self.headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true) // HTTP/1.1 default
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Status code.
    pub status: u16,
    /// Content-Type header value.
    pub content_type: String,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// A JSON response.
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json".into(),
            body: body.into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            body: body.into().into_bytes(),
        }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }

    /// Serialize status line, headers, and body to `w`.
    pub fn write(&self, w: &mut impl Write, keep_alive: bool) -> Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// Minimal blocking HTTP/1.1 client call: one request, one response,
/// connection closed.  Returns `(status, body)`.  This is what the
/// `bitkernel mount`/`unmount`/`reload` CLI subcommands and the
/// lifecycle smoke example speak to the admin API with — deliberately
/// tiny (no keep-alive, no chunked bodies, 30 s timeouts) so the CLI
/// needs no client dependency.
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    use std::net::TcpStream;
    use std::time::Duration;

    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status")?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .with_context(|| format!("bad status line '{status_line}'"))?
        .parse()
        .context("bad status code")?;
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        ensure!(reader.read_line(&mut line)? > 0, "eof in headers");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    ensure!(len <= 16 << 20, "response too large ({len} bytes)");
    let mut out = vec![0u8; len];
    reader.read_exact(&mut out).context("reading body")?;
    Ok((status, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &str) -> HttpRequest {
        HttpRequest::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap()
            .unwrap()
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /classify?model=bnn&x=1 HTTP/1.1\r\nHost: a\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/classify");
        assert_eq!(r.query.get("model").map(String::as_str), Some("bnn"));
        assert_eq!(r.query.get("x").map(String::as_str), Some("1"));
        assert!(r.wants_keep_alive());
    }

    #[test]
    fn parses_post_body() {
        let r = parse(
            "POST /c HTTP/1.1\r\nContent-Length: 5\r\nConnection: close\r\n\r\nhello",
        );
        assert_eq!(r.body, b"hello");
        assert!(!r.wants_keep_alive());
    }

    #[test]
    fn eof_returns_none() {
        let r = HttpRequest::read(&mut BufReader::new(&b""[..])).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn rejects_bad_version_and_huge_body() {
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"GET / SPDY/99\r\n\r\n"[..]
        ))
        .is_err());
        assert!(HttpRequest::read(&mut BufReader::new(
            &b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n"[..]
        ))
        .is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = HttpResponse::json(200, "{\"ok\":true}".into());
        let mut buf = Vec::new();
        resp.write(&mut buf, true).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }
}
