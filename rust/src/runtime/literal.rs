//! Tensor <-> xla::Literal bridge.

use anyhow::{ensure, Result};

use crate::tensor::Tensor;

/// Dense f32 tensor -> an XLA literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Raw u32 words -> an XLA literal with the given shape.
pub fn u32s_to_literal(words: &[u32], shape: &[usize]) -> Result<xla::Literal> {
    ensure!(shape.iter().product::<usize>() == words.len(),
            "shape {shape:?} vs {} words", words.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(words).reshape(&dims)?)
}

/// Flatten an f32 literal back into a Vec.
pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
