//! artifacts/manifest.json — the contract between aot.py and this crate.
//!
//! The manifest records, for every lowered executable, the exact
//! flattened HLO parameter order with a recipe for building each
//! argument from the BKW1 weight file (`transform`), so the rust side
//! never has to re-derive jax pytree flattening rules.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::utils::json::Json;

/// What an HLO parameter is built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    /// Built from the weight file.
    Weight,
    /// The request image batch.
    Image,
}

/// How to build an HLO parameter from its source tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transform {
    /// Load `source` as-is.
    None,
    /// Reshape [D, ...] -> [D, K], sign-binarize, bit-pack rows.
    PackRows,
}

/// One HLO parameter of a lowered model.
#[derive(Debug, Clone)]
pub struct InputDesc {
    /// Parameter name in the HLO signature.
    pub name: String,
    /// Weight-derived or the image slot.
    pub kind: InputKind,
    /// Element type: "f32" | "u32".
    pub dtype: String,
    /// Parameter shape.
    pub shape: Vec<usize>,
    /// Recipe from source tensor to parameter.
    pub transform: Transform,
    /// Weight-file tensor name (`None` for the image slot).
    pub source: Option<String>,
    /// Unpadded reduction length for packed parameters.
    pub logical_k: Option<usize>,
}

/// One whole-model executable.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Unique model name.
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Kernel arm: xnor | control | optimized.
    pub variant: String,
    /// Width scale relative to the paper's full model.
    pub scale: f64,
    /// Batch size baked at AOT time.
    pub batch: usize,
    /// Weight set: "small" | "full".
    pub weights: String,
    /// HLO parameters, in signature order.
    pub inputs: Vec<InputDesc>,
    /// Logits shape.
    pub output_shape: Vec<usize>,
}

/// One kernel micro executable.
#[derive(Debug, Clone)]
pub struct KernelEntry {
    /// Unique kernel name.
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Kernel arm: xnor | control | optimized.
    pub kernel: String,
    /// Layer tag: conv2 | conv4 | conv6 | fc1b8.
    pub tag: String,
    /// Output rows.
    pub d: usize,
    /// Reduction length.
    pub k: usize,
    /// Output columns.
    pub n: usize,
}

/// Weight-file metadata.
#[derive(Debug, Clone)]
pub struct WeightsEntry {
    /// Weight-set name ("small" | "full").
    pub name: String,
    /// BKW1 file, relative to the artifacts dir.
    pub file: String,
    /// Width scale relative to the paper's full model.
    pub scale: f64,
    /// Whether the weights were actually trained.
    pub trained: bool,
}

/// The parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and every relative path) lives in.
    pub dir: PathBuf,
    /// Whole-model executables.
    pub models: Vec<ModelEntry>,
    /// Kernel micro executables.
    pub kernels: Vec<KernelEntry>,
    /// Weight files.
    pub weights: Vec<WeightsEntry>,
    /// Test-dataset file, when present.
    pub test_dataset: Option<String>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().context("shape dim"))
        .collect()
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .with_context(|| format!("missing '{key}'"))?
        .as_str()
        .with_context(|| format!("'{key}' not a string"))?
        .to_string())
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let root = Json::parse(&text).context("parse manifest.json")?;

        let mut models = Vec::new();
        for m in root.get("models").context("models")?.as_arr().unwrap_or(&[])
        {
            let mut inputs = Vec::new();
            for inp in m.get("inputs").context("inputs")?.as_arr().unwrap_or(&[]) {
                let kind = match str_of(inp, "kind")?.as_str() {
                    "weight" => InputKind::Weight,
                    "image" => InputKind::Image,
                    other => bail!("unknown input kind '{other}'"),
                };
                let transform = match str_of(inp, "transform")?.as_str() {
                    "none" => Transform::None,
                    "pack_rows" => Transform::PackRows,
                    other => bail!("unknown transform '{other}'"),
                };
                inputs.push(InputDesc {
                    name: str_of(inp, "name")?,
                    kind,
                    dtype: str_of(inp, "dtype")?,
                    shape: shape_of(inp.get("shape").context("shape")?)?,
                    transform,
                    source: inp
                        .get("source")
                        .and_then(|s| s.as_str())
                        .map(String::from),
                    logical_k: inp.get("logical_k").and_then(|k| k.as_usize()),
                });
            }
            models.push(ModelEntry {
                name: str_of(m, "name")?,
                file: str_of(m, "file")?,
                variant: str_of(m, "variant")?,
                scale: m.get("scale").and_then(|s| s.as_f64()).unwrap_or(1.0),
                batch: m.get("batch").and_then(|b| b.as_usize()).context("batch")?,
                weights: str_of(m, "weights")?,
                inputs,
                output_shape: shape_of(
                    m.get("output").context("output")?.get("shape").context("output.shape")?,
                )?,
            });
        }

        let mut kernels = Vec::new();
        for k in root.get("kernels").map(|k| k.as_arr().unwrap_or(&[])).unwrap_or(&[]) {
            kernels.push(KernelEntry {
                name: str_of(k, "name")?,
                file: str_of(k, "file")?,
                kernel: str_of(k, "kernel")?,
                tag: str_of(k, "tag")?,
                d: k.get("d").and_then(|v| v.as_usize()).context("d")?,
                k: k.get("k").and_then(|v| v.as_usize()).context("k")?,
                n: k.get("n").and_then(|v| v.as_usize()).context("n")?,
            });
        }

        let mut weights = Vec::new();
        if let Some(Json::Obj(map)) = root.get("weights") {
            for (name, w) in map {
                weights.push(WeightsEntry {
                    name: name.clone(),
                    file: str_of(w, "file")?,
                    scale: w.get("scale").and_then(|s| s.as_f64()).unwrap_or(1.0),
                    trained: w
                        .get("trained")
                        .and_then(|t| t.as_bool())
                        .unwrap_or(false),
                });
            }
        }

        let test_dataset = root
            .get("datasets")
            .and_then(|d| d.get("test"))
            .and_then(|t| t.get("file"))
            .and_then(|f| f.as_str())
            .map(String::from);

        Ok(Self { dir, models, kernels, weights, test_dataset })
    }

    /// Look a model up by exact name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }

    /// Find a model by (scale name, variant, batch).
    pub fn find_model(
        &self,
        weights: &str,
        variant: &str,
        batch: usize,
    ) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.weights == weights && m.variant == variant
                  && m.batch == batch)
            .with_context(|| {
                format!("no model for weights={weights} variant={variant} batch={batch}")
            })
    }

    /// Absolute path of the named weight set's BKW1 file.
    pub fn weight_file(&self, name: &str) -> Result<PathBuf> {
        let w = self
            .weights
            .iter()
            .find(|w| w.name == name)
            .with_context(|| format!("weights '{name}'"))?;
        Ok(self.dir.join(&w.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_sample(dir: &Path) {
        let text = r#"{
          "format": 1,
          "models": [{
            "name": "bnn_small_xnor_b1", "file": "m.hlo.txt",
            "variant": "xnor", "scale": 0.25, "batch": 1,
            "weights": "small",
            "inputs": [
              {"name": "conv1.w", "kind": "weight", "dtype": "f32",
               "shape": [8,3,3,3], "transform": "none", "source": "conv1.w"},
              {"name": "conv2.wp", "kind": "weight", "dtype": "u32",
               "shape": [8,3], "transform": "pack_rows",
               "source": "conv2.w", "logical_k": 72},
              {"name": "x", "kind": "image", "dtype": "f32",
               "shape": [1,3,32,32], "transform": "none", "source": null}
            ],
            "output": {"dtype": "f32", "shape": [1, 10]}
          }],
          "kernels": [{"name": "k_xnor_conv2", "file": "k.hlo.txt",
                       "kernel": "xnor", "tag": "conv2",
                       "d": 128, "k": 1152, "n": 1024,
                       "inputs": [], "logical_k": 1152}],
          "weights": {"small": {"file": "w.bkw", "scale": 0.25,
                      "trained": true}},
          "datasets": {"test": {"file": "ds.bin", "count": 7}}
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parse_sample_manifest() {
        let dir = std::env::temp_dir().join("bk_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1);
        let model = m.find_model("small", "xnor", 1).unwrap();
        assert_eq!(model.inputs.len(), 3);
        assert_eq!(model.inputs[1].transform, Transform::PackRows);
        assert_eq!(model.inputs[1].logical_k, Some(72));
        assert_eq!(model.inputs[2].kind, InputKind::Image);
        assert_eq!(model.output_shape, vec![1, 10]);
        assert_eq!(m.kernels[0].d, 128);
        assert_eq!(m.weight_file("small").unwrap(),
                   dir.join("w.bkw"));
        assert_eq!(m.test_dataset.as_deref(), Some("ds.bin"));
        assert!(m.find_model("small", "xnor", 99).is_err());
    }
}
