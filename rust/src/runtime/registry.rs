//! The executable registry: PJRT client + lazily-compiled AOT models.
//!
//! `Runtime::load_model` reads the HLO text (the 64-bit-id-safe
//! interchange format), compiles it on the CPU PJRT client, pre-builds
//! every weight argument literal from the BKW1 file per the manifest's
//! input recipes, and returns a [`LoadedModel`] whose `infer` needs only
//! the image batch — the serving hot path.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::bitops::pack_rows;
use crate::model::format::WeightFile;
use crate::nn::sign_inplace;
use crate::tensor::Tensor;

use super::literal::{tensor_to_literal, u32s_to_literal};
use super::manifest::{InputKind, Manifest, ModelEntry, Transform};

/// A compiled whole-model executable with its weight literals baked.
pub struct LoadedModel {
    /// Model name from the manifest.
    pub name: String,
    /// Kernel arm: xnor | control | optimized.
    pub variant: String,
    /// Batch size baked at AOT time.
    pub batch: usize,
    /// Logits shape.
    pub output_shape: Vec<usize>,
    exe: xla::PjRtLoadedExecutable,
    /// Literals for every HLO parameter; the image slot is rebuilt per
    /// call (index `image_idx`).
    weight_literals: Vec<Option<xla::Literal>>,
    image_idx: usize,
    image_shape: Vec<usize>,
}

impl LoadedModel {
    /// Per-image input shape (C, H, W) the executable was lowered for
    /// (from the manifest's image parameter, [batch, C, H, W]).
    pub fn input_shape(&self) -> (usize, usize, usize) {
        (self.image_shape[1], self.image_shape[2], self.image_shape[3])
    }

    /// Output class count (logits are [batch, classes]).
    pub fn classes(&self) -> usize {
        self.output_shape[1]
    }

    /// Run one batch: normalized NCHW images -> logits [batch, 10].
    pub fn infer(&self, images: &Tensor) -> Result<Tensor> {
        ensure!(
            images.shape() == self.image_shape,
            "image shape {:?}, executable wants {:?}",
            images.shape(),
            self.image_shape
        );
        let image_lit = tensor_to_literal(images)?;
        // Assemble the argument list (weights are pre-built literals).
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(self.weight_literals.len());
        for (i, slot) in self.weight_literals.iter().enumerate() {
            if i == self.image_idx {
                args.push(&image_lit);
            } else {
                args.push(slot.as_ref().expect("weight literal"));
            }
        }
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Tensor::new(self.output_shape.clone(), values))
    }
}

/// PJRT client + manifest + loaded-model cache.
pub struct Runtime {
    /// The parsed artifact manifest.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    weight_files: HashMap<String, WeightFile>,
    models: HashMap<String, LoadedModel>,
}

impl Runtime {
    /// Open the PJRT CPU client over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Self {
            manifest,
            client,
            weight_files: HashMap::new(),
            models: HashMap::new(),
        })
    }

    fn weight_file(&mut self, name: &str) -> Result<&WeightFile> {
        if !self.weight_files.contains_key(name) {
            let path = self.manifest.weight_file(name)?;
            let wf = WeightFile::load(&path)?;
            self.weight_files.insert(name.to_string(), wf);
        }
        Ok(&self.weight_files[name])
    }

    /// Compile (or fetch from cache) a whole-model executable.
    pub fn load_model(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(name) {
            let entry = self.manifest.model(name)?.clone();
            let model = self.build_model(&entry)?;
            self.models.insert(name.to_string(), model);
        }
        Ok(&self.models[name])
    }

    /// Find by (weights, variant, batch) and load.
    pub fn load_by(
        &mut self,
        weights: &str,
        variant: &str,
        batch: usize,
    ) -> Result<&LoadedModel> {
        let name = self
            .manifest
            .find_model(weights, variant, batch)?
            .name
            .clone();
        self.load_model(&name)
    }

    fn build_model(&mut self, entry: &ModelEntry) -> Result<LoadedModel> {
        let hlo_path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("hlo path utf-8")?,
        )
        .with_context(|| format!("parse {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", entry.name))?;

        let wf = self.weight_file(&entry.weights)?;
        let mut weight_literals = Vec::with_capacity(entry.inputs.len());
        let mut image_idx = None;
        let mut image_shape = Vec::new();
        for (i, inp) in entry.inputs.iter().enumerate() {
            match inp.kind {
                InputKind::Image => {
                    ensure!(image_idx.is_none(), "two image inputs");
                    image_idx = Some(i);
                    image_shape = inp.shape.clone();
                    weight_literals.push(None);
                }
                InputKind::Weight => {
                    let src = inp.source.as_deref().context("source")?;
                    let t = wf.get(src)?;
                    let lit = match inp.transform {
                        Transform::None => {
                            let vals = t.as_f32()?;
                            ensure!(
                                vals.len()
                                    == inp.shape.iter().product::<usize>(),
                                "{}: {} elems vs shape {:?}",
                                inp.name,
                                vals.len(),
                                inp.shape
                            );
                            tensor_to_literal(&Tensor::new(
                                inp.shape.clone(),
                                vals,
                            ))?
                        }
                        Transform::PackRows => {
                            let mut vals = t.as_f32()?;
                            sign_inplace(&mut vals);
                            let d = inp.shape[0];
                            let k = inp
                                .logical_k
                                .context("pack_rows needs logical_k")?;
                            ensure!(vals.len() == d * k,
                                    "{}: {} vs {}x{}", inp.name,
                                    vals.len(), d, k);
                            let packed = pack_rows(&vals, d, k);
                            ensure!(packed.kw == inp.shape[1],
                                    "{}: kw {} vs shape {:?}", inp.name,
                                    packed.kw, inp.shape);
                            u32s_to_literal(&packed.data, &inp.shape)?
                        }
                    };
                    weight_literals.push(Some(lit));
                }
            }
        }

        Ok(LoadedModel {
            name: entry.name.clone(),
            variant: entry.variant.clone(),
            batch: entry.batch,
            output_shape: entry.output_shape.clone(),
            exe,
            weight_literals,
            image_idx: image_idx.context("model has no image input")?,
            image_shape,
        })
    }

    /// Remove a loaded model from the cache and hand it to the caller
    /// (e.g. to move it into a worker thread's backend).
    pub fn take_model(&mut self, name: &str) -> Result<LoadedModel> {
        self.models
            .remove(name)
            .with_context(|| format!("model '{name}' not loaded"))
    }

    /// Compile a kernel micro executable (benches).  Returns the
    /// executable directly — kernels take raw literals.
    pub fn load_kernel(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let entry = self
            .manifest
            .kernels
            .iter()
            .find(|k| k.name == name)
            .with_context(|| format!("kernel '{name}'"))?;
        let hlo_path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
