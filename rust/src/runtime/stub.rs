//! No-PJRT stand-ins for `super::registry` (absent in this
//! configuration, hence no link), compiled when the `pjrt`
//! feature is off (the default: the xla native library is a heavy,
//! often-unavailable build dependency, and only the Table-2
//! "accelerator" arm needs it).
//!
//! Type-compatible with the real registry so every caller — the CLI's
//! pjrt backends, [`crate::coordinator::PjrtBackend`], benchkit's
//! table2 — compiles unchanged; construction fails at runtime with a
//! clear "rebuild with `--features pjrt`" error instead.  Neither type
//! can actually be instantiated in this configuration.

use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::manifest::Manifest;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: bitkernel was built without the `pjrt` \
     feature (rebuild with `cargo build --features pjrt`)";

/// Stub of the compiled whole-model executable.  Unconstructible: the
/// only producer is [`Runtime`], whose constructor always errors here.
pub struct LoadedModel {
    /// Model name from the manifest.
    pub name: String,
    /// Kernel arm: xnor | control | optimized.
    pub variant: String,
    /// Batch size baked at AOT time.
    pub batch: usize,
    /// Logits shape.
    pub output_shape: Vec<usize>,
    #[allow(dead_code)]
    unconstructible: (),
}

impl LoadedModel {
    /// Per-image input shape (C, H, W) — unreachable: this stub type
    /// cannot be constructed (its only producer always errors).
    pub fn input_shape(&self) -> (usize, usize, usize) {
        unreachable!("stub LoadedModel is unconstructible")
    }

    /// Output class count (logits are [batch, classes]).
    pub fn classes(&self) -> usize {
        self.output_shape[1]
    }

    /// Always errors (built without `pjrt`).
    pub fn infer(&self, _images: &Tensor) -> Result<Tensor> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the PJRT client + model registry.
pub struct Runtime {
    /// The parsed artifact manifest.
    pub manifest: Manifest,
}

impl Runtime {
    /// Always errors (built without `pjrt`).
    pub fn new(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    /// Always errors (built without `pjrt`).
    pub fn load_model(&mut self, _name: &str) -> Result<&LoadedModel> {
        bail!(UNAVAILABLE)
    }

    /// Always errors (built without `pjrt`).
    pub fn load_by(
        &mut self,
        _weights: &str,
        _variant: &str,
        _batch: usize,
    ) -> Result<&LoadedModel> {
        bail!(UNAVAILABLE)
    }

    /// Always errors (built without `pjrt`).
    pub fn take_model(&mut self, _name: &str) -> Result<LoadedModel> {
        bail!(UNAVAILABLE)
    }

    /// Reports the platform as unavailable.
    pub fn platform(&self) -> String {
        "unavailable (built without pjrt)".to_string()
    }
}
