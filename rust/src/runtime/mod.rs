//! The PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the Table-2 "accelerator" arm: the whole-model inference
//! graphs that python lowered (Pallas xnor / Pallas control / XLA
//! optimized) are compiled once by the PJRT CPU client and then executed
//! from the rust hot path with zero python involvement.

pub mod literal;
pub mod manifest;
pub mod registry;

pub use literal::{literal_to_vec_f32, tensor_to_literal, u32s_to_literal};
pub use manifest::{InputDesc, InputKind, KernelEntry, Manifest, ModelEntry, Transform};
pub use registry::{LoadedModel, Runtime};
