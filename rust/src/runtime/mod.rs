//! The PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the Table-2 "accelerator" arm: the whole-model inference
//! graphs that python lowered (Pallas xnor / Pallas control / XLA
//! optimized) are compiled once by the PJRT CPU client and then executed
//! from the rust hot path with zero python involvement.
//!
//! The PJRT client (and its `xla` native-library dependency) is gated
//! behind the `pjrt` cargo feature.  Without it, [`Runtime`] and
//! [`LoadedModel`] are type-compatible stubs whose constructors return
//! a "rebuild with `--features pjrt`" error — the native engine, the
//! coordinator, and every bench build and run regardless.

#[cfg(feature = "pjrt")]
pub mod literal;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod registry;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use literal::{literal_to_vec_f32, tensor_to_literal, u32s_to_literal};
pub use manifest::{InputDesc, InputKind, KernelEntry, Manifest, ModelEntry, Transform};
#[cfg(feature = "pjrt")]
pub use registry::{LoadedModel, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, Runtime};
