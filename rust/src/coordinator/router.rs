//! Router: bounded admission queue -> dynamic batcher -> replica pool.
//!
//! One [`Router`] drives a pool of `cfg.replicas` worker threads, each
//! holding its own [`Backend`] (for the native engine: one `Session`
//! minted per replica from one shared compiled `Plan` — see
//! [`super::backend::NativeBackend::from_plan`]).  Submission is
//! non-blocking with explicit backpressure (`SubmitError::QueueFull`
//! when the admission queue is at capacity); replies come back over
//! per-request channels.
//!
//! The pipeline:
//!
//! ```text
//!     submit -> bounded queue -> batcher thread -(least-loaded)->
//!         replica 0..N worker threads -> per-request reply channels
//! ```
//!
//! The batcher forms max-size/max-delay batches and hands each one to
//! the replica with the fewest in-flight requests (tracked in
//! [`Metrics::replicas`]).  Per-replica dispatch channels are bounded
//! to one queued batch, so when every replica is saturated the
//! admission queue fills and callers see `QueueFull` — backpressure is
//! preserved end to end.  [`Router::shutdown`] drains: every accepted
//! request is batched, dispatched and answered before the threads are
//! joined.  A serving deployment maps model names to routers (see
//! `server/`).
//!
//! The router is **shape-generic**: at [`Router::start`] it captures
//! the backends' shape contract ([`Backend::input_shape`] /
//! [`Backend::classes`] / [`Backend::labels`]), validates every
//! [`Router::submit`] against it (wrong-sized images are a typed
//! [`SubmitError::WrongShape`], never a worker panic), and sizes each
//! replica's reusable padded batch tensor from it.  A single process
//! can therefore pool routers over heterogeneous models — a
//! 3x32x32/10-class CNN next to a 1x28x28/26-class fc net — with no
//! geometry hardwired anywhere on the request path.
//!
//! **Retiring a shared router.**  `Drop` runs the same drain as
//! [`Router::shutdown`], which makes `Arc<Router>` the hot-swap
//! primitive the model registry (`server/registry.rs`) builds on: the
//! registry publishes `Arc<Router>` handles, every in-flight request
//! holds a clone, and a reload/unmount simply swaps the published
//! handle and lets the *last* clone's drop drain the old
//! pipeline — accepted requests are answered by whichever generation
//! admitted them, and no request is ever dropped mid-swap.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::nn::argmax;

use super::backend::Backend;
use super::batcher::{BatchBuffer, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;

/// A completed inference.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Argmax class index.
    pub class: usize,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Time from submit to batch formation.
    pub queue_us: u64,
    /// Time from submit to reply.
    pub total_us: u64,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue at capacity — caller should retry/shed.
    QueueFull,
    /// The image's element count does not match the model's input
    /// shape (`C*H*W` — see [`Router::input_shape`]).
    WrongShape {
        /// Elements the model's input shape requires.
        expected: usize,
        /// Elements the submission carried.
        got: usize,
    },
    /// Router shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::WrongShape { expected, got } => write!(
                f,
                "image has {got} elements, model expects {expected}"
            ),
            SubmitError::Shutdown => write!(f, "router shut down"),
        }
    }
}

struct Request {
    /// Normalized CHW image (`C*H*W` f32, validated at submit).
    image: Vec<f32>,
    submitted: Instant,
    reply_tx: mpsc::Sender<InferReply>,
}

/// A formed batch in flight from the batcher to a replica.
struct Batch {
    /// When the batcher closed the batch (queue-latency reference).
    formed: Instant,
    reqs: Vec<Request>,
}

/// A backend constructor, called once per replica (with the replica
/// index) inside that replica's worker thread.
pub type BackendFactory =
    dyn Fn(usize) -> anyhow::Result<Box<dyn Backend>> + Send + Sync;

/// Default replica count: one worker per core the host exposes, capped
/// at 8 (large gemm ops inside a native replica already fan out on the
/// plan's shared thread pool, so more replicas than cores only adds
/// contention).
pub fn default_replicas() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Worker replicas behind the batcher (>= 1).  Defaults to
    /// [`default_replicas`].
    pub replicas: usize,
    /// Batch-formation policy.
    pub batcher: BatcherConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            replicas: default_replicas(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// What a replica reports once its backend is constructed: the
/// metrics label plus the backend's full shape contract.
struct ReplicaInfo {
    name: String,
    cap: usize,
    shape: (usize, usize, usize),
    classes: usize,
    labels: Option<Vec<String>>,
}

/// A running pipeline: queue -> batcher -> replica pool.
pub struct Router {
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    backend_name: String,
    replicas: usize,
    /// Shape contract captured from the backends at startup.
    input_shape: (usize, usize, usize),
    classes: usize,
    labels: Option<Vec<String>>,
}

impl Router {
    /// Spawn the replica pool and batcher; the backends are constructed
    /// INSIDE their worker threads via `factory` (PJRT handles are not
    /// `Send`), called once per replica with the replica index.
    /// Construction errors on any replica are surfaced synchronously
    /// and tear the whole pool down.
    ///
    /// For the native engine, compile the plan ONCE outside and let
    /// every call mint a session from it:
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, NativeBackend, Router,
    ///                              RouterConfig};
    /// use bitkernel::model::EngineKernel;
    /// use bitkernel::bitops::XnorImpl;
    ///
    /// let engine = bitkernel::testing::synthetic_engine(
    ///     [8, 8, 8, 8, 8, 8, 16, 16, 10], 1);
    /// let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4).unwrap();
    /// let router = Router::start(
    ///     move |_replica| {
    ///         Ok(Box::new(NativeBackend::from_plan(&plan))
    ///             as Box<dyn Backend>)
    ///     },
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// assert_eq!(router.replicas(), 2);
    /// router.shutdown();
    /// ```
    pub fn start<F>(factory: F, cfg: RouterConfig) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn Backend>>
            + Send
            + Sync
            + 'static,
    {
        assert!(cfg.replicas >= 1, "need at least one replica");
        let replicas = cfg.replicas;
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::with_replicas(replicas));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) =
            mpsc::channel::<anyhow::Result<ReplicaInfo>>();

        // Per-replica dispatch channels are bounded to ONE queued batch:
        // enough to keep a replica busy back to back, small enough that
        // saturation propagates to the admission queue (backpressure).
        let mut workers = Vec::with_capacity(replicas);
        let mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>> =
            Vec::with_capacity(replicas);
        for r in 0..replicas {
            let (btx, brx) = mpsc::sync_channel::<Batch>(1);
            batch_txs.push(Some(btx));
            let f = Arc::clone(&factory);
            let m = Arc::clone(&metrics);
            let rtx = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bk-replica{r}"))
                    .spawn(move || replica_loop(r, &*f, brx, &m, rtx))
                    .expect("spawn replica worker"),
            );
        }
        drop(ready_tx);

        // Collect startup results; the smallest backend capacity bounds
        // batch formation so every batch fits every replica, and every
        // replica must publish the SAME shape contract (one factory,
        // one model — a mismatch is a backend bug surfaced here, not a
        // worker panic later).
        let mut backend_name = String::new();
        let mut min_cap = usize::MAX;
        let mut contract: Option<((usize, usize, usize), usize)> = None;
        let mut labels: Option<Vec<String>> = None;
        for _ in 0..replicas {
            let result = match ready_rx.recv() {
                Ok(r) => r,
                // A worker died without reporting (panicked in factory).
                Err(_) => Err(anyhow::anyhow!(
                    "replica worker died during startup"
                )),
            };
            let result = result.and_then(|info| {
                match contract {
                    None => contract = Some((info.shape, info.classes)),
                    Some(c) if c != (info.shape, info.classes) => {
                        anyhow::bail!(
                            "replica shape contracts disagree: \
                             {:?}/{} vs {:?}/{}",
                            c.0, c.1, info.shape, info.classes
                        )
                    }
                    Some(_) => {}
                }
                Ok(info)
            });
            match result {
                Ok(info) => {
                    backend_name = info.name;
                    min_cap = min_cap.min(info.cap);
                    if labels.is_none() {
                        labels = info.labels;
                    }
                }
                Err(e) => {
                    // Tear the pool down: dropping the dispatch channels
                    // ends every replica that did start.
                    drop(batch_txs);
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }
        let (input_shape, classes) =
            contract.expect("replicas >= 1 reported");

        let bcfg = BatcherConfig {
            // Never form batches larger than the smallest backend.
            max_batch: cfg.batcher.max_batch.min(min_cap),
            max_delay: cfg.batcher.max_delay,
        };
        let m = Arc::clone(&metrics);
        let batcher = std::thread::Builder::new()
            .name("bk-batcher".to_string())
            .spawn(move || batcher_loop(rx, bcfg, batch_txs, &m))
            .expect("spawn batcher");

        Ok(Self {
            tx: Some(tx),
            metrics,
            batcher: Some(batcher),
            workers,
            backend_name,
            replicas,
            input_shape,
            classes,
            labels,
        })
    }

    /// Label of the backend the pool runs (all replicas share one
    /// factory, hence one label).
    pub fn backend_name(&self) -> &str {
        &self.backend_name
    }

    /// Per-image input shape (C, H, W) this router's model expects —
    /// the shape contract captured from the backends at startup.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.input_shape
    }

    /// Elements of one request image (`C*H*W`) — the length
    /// [`Router::submit`] validates against.
    pub fn image_elems(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Number of output classes (reply logits have this length).
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// The model's class-label table, when it carries one
    /// (`labels()[c]` names class `c`).
    pub fn labels(&self) -> Option<&[String]> {
        self.labels.as_deref()
    }

    /// Display name for `class`: the label table's entry, or the
    /// numeric index for label-less models
    /// ([`crate::model::label_for`]).
    pub fn label_for(&self, class: usize) -> String {
        crate::model::label_for(self.labels(), class)
    }

    /// Number of worker replicas in the pool.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Shared handle to the router's counters.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Non-blocking submit; returns the reply channel.  The image must
    /// have exactly [`Router::image_elems`] elements (the model's
    /// `C*H*W`) — anything else is a typed
    /// [`SubmitError::WrongShape`], checked here at admission so a
    /// malformed request can never reach (let alone panic) a worker.
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, MockBackend, Router,
    ///                              RouterConfig, SubmitError};
    ///
    /// let router = Router::start(
    ///     |_replica| Ok(Box::new(MockBackend::new(4, 0))
    ///                   as Box<dyn Backend>),
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// assert_eq!(router.input_shape(), (3, 32, 32));
    /// let rx = router.submit(vec![0.5; router.image_elems()]).unwrap();
    /// let reply = rx.recv().unwrap();
    /// assert_eq!(reply.logits.len(), router.classes());
    /// assert!(matches!(router.submit(vec![0.5; 7]),
    ///                  Err(SubmitError::WrongShape { .. })));
    /// router.shutdown();
    /// ```
    pub fn submit(
        &self,
        image_chw: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        let expected = self.image_elems();
        if image_chw.len() != expected {
            return Err(SubmitError::WrongShape {
                expected,
                got: image_chw.len(),
            });
        }
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            image: image_chw,
            submitted: Instant::now(),
            reply_tx,
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Submit and block for the reply.
    pub fn submit_wait(&self, image_chw: Vec<f32>) -> Result<InferReply, SubmitError> {
        let rx = self.submit(image_chw)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Graceful drain: stop admissions, let the batcher flush every
    /// queued request through the replicas, then join all threads.  No
    /// accepted request is dropped.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        drop(self.tx.take());
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.drain();
    }
}

/// One replica worker: construct the backend, report readiness, then
/// execute dispatched batches until the batcher hangs up.
fn replica_loop(
    replica: usize,
    factory: &BackendFactory,
    brx: mpsc::Receiver<Batch>,
    m: &Metrics,
    ready_tx: mpsc::Sender<anyhow::Result<ReplicaInfo>>,
) {
    let mut backend = match factory(replica) {
        Ok(b) => {
            let _ = ready_tx.send(Ok(ReplicaInfo {
                name: b.name().to_string(),
                cap: b.max_batch(),
                shape: b.input_shape(),
                classes: b.classes(),
                labels: b.labels().map(<[String]>::to_vec),
            }));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);
    let cap = backend.max_batch();
    // The replica's reusable padded input tensor, sized from the
    // backend's shape contract — refilled in place per batch, so the
    // dispatch hot path allocates nothing for image data.
    let mut buffer = BatchBuffer::new(cap, backend.input_shape());
    let rm = &m.replicas[replica];
    while let Ok(batch) = brx.recv() {
        let Batch { formed, reqs } = batch;
        let b = reqs.len();
        let images = buffer.fill(reqs.iter().map(|r| &r.image[..]));
        let infer_sw = Instant::now();
        let result = backend.infer(images);
        let infer_us = infer_sw.elapsed().as_micros() as u64;
        rm.batches.fetch_add(1, Ordering::Relaxed);
        rm.requests.fetch_add(b as u64, Ordering::Relaxed);
        rm.busy_us.fetch_add(infer_us, Ordering::Relaxed);
        rm.infer_latency.record_us(infer_us);
        match result {
            Ok(logits) => {
                let done = Instant::now();
                for (i, r) in reqs.into_iter().enumerate() {
                    let row = logits.row(i).to_vec();
                    let reply = InferReply {
                        class: argmax(&row),
                        logits: row,
                        queue_us: (formed - r.submitted).as_micros() as u64,
                        total_us: (done - r.submitted).as_micros() as u64,
                    };
                    m.total_latency.record_us(reply.total_us);
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply_tx.send(reply);
                }
            }
            Err(e) => {
                crate::log_error!(
                    "replica {replica} inference failed: {e:#}"
                );
                // Drop the requests; their reply channels disconnect,
                // which callers observe as an error.
                m.rejected.fetch_add(b as u64, Ordering::Relaxed);
            }
        }
        rm.inflight.fetch_sub(b as u64, Ordering::Relaxed);
    }
}

/// The batcher thread: form batches, dispatch each to the least-loaded
/// replica.  Exits (dropping the dispatch channels, which drains the
/// workers) when every submitter hung up and the queue is empty.
fn batcher_loop(
    rx: mpsc::Receiver<Request>,
    bcfg: BatcherConfig,
    mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>>,
    m: &Metrics,
) {
    let batcher = DynamicBatcher::new(rx, bcfg);
    while let Some(reqs) = batcher.next_batch() {
        let formed = Instant::now();
        let b = reqs.len();
        m.batches.fetch_add(1, Ordering::Relaxed);
        m.batched_requests.fetch_add(b as u64, Ordering::Relaxed);
        for r in &reqs {
            m.queue_latency
                .record_us((formed - r.submitted).as_micros() as u64);
        }
        dispatch(Batch { formed, reqs }, &mut batch_txs, m);
    }
}

/// Least-loaded dispatch: try replicas in ascending in-flight order
/// without blocking; if every dispatch slot is full, block on the
/// least-loaded live replica (which stalls the batcher and, in turn,
/// fills the admission queue — the backpressure path).  Replicas whose
/// worker died are retired from the rotation.
fn dispatch(
    mut batch: Batch,
    batch_txs: &mut [Option<mpsc::SyncSender<Batch>>],
    m: &Metrics,
) {
    let b = batch.reqs.len() as u64;
    loop {
        let mut order: Vec<usize> = (0..batch_txs.len())
            .filter(|&r| batch_txs[r].is_some())
            .collect();
        if order.is_empty() {
            // Every replica died: shed the batch (reply channels drop).
            m.rejected.fetch_add(b, Ordering::Relaxed);
            return;
        }
        order.sort_by_key(|&r| {
            m.replicas[r].inflight.load(Ordering::Relaxed)
        });
        // Pass 1: non-blocking, in load order.
        for &r in &order {
            let rm = &m.replicas[r];
            rm.inflight.fetch_add(b, Ordering::Relaxed);
            match batch_txs[r].as_ref().unwrap().try_send(batch) {
                Ok(()) => return,
                Err(mpsc::TrySendError::Full(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch = back;
                }
                Err(mpsc::TrySendError::Disconnected(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch_txs[r] = None;
                    batch = back;
                }
            }
        }
        // Pass 2: every slot full — block on the least-loaded replica.
        let r = order[0];
        if batch_txs[r].is_none() {
            continue; // retired during pass 1; recompute the order
        }
        let rm = &m.replicas[r];
        rm.inflight.fetch_add(b, Ordering::Relaxed);
        match batch_txs[r].as_ref().unwrap().send(batch) {
            Ok(()) => return,
            Err(mpsc::SendError(back)) => {
                rm.inflight.fetch_sub(b, Ordering::Relaxed);
                batch_txs[r] = None;
                batch = back;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn image(v: f32) -> Vec<f32> {
        vec![v; 3 * 32 * 32]
    }

    #[test]
    fn submit_roundtrip() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let reply = router.submit_wait(image(0.9)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        assert!(reply.class >= 8, "{}", reply.class); // high mean -> high class
        assert!(reply.total_us >= reply.queue_us);
        let snap = router.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.replicas.len(), router.replicas());
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            1
        );
    }

    #[test]
    fn batches_multiple_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_calls(
                    8,
                    5,
                    Arc::clone(&calls2),
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 64,
                replicas: 1, // a single replica pins the batch count
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(50),
                },
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // All 8 should have ridden one or two batches, not 8 singles.
        let n = calls.load(Ordering::SeqCst);
        assert!(n <= 2, "backend called {n} times");
        assert!(router.metrics().snapshot().mean_batch_size >= 4.0);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow backend + tiny queue -> QueueFull.
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 50)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 2,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let mut rejected = 0;
        let mut kept = Vec::new();
        for _ in 0..20 {
            match router.submit(image(0.0)) {
                Ok(rx) => kept.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "expected rejections");
        for rx in kept {
            let _ = rx.recv();
        }
        assert_eq!(router.metrics().snapshot().rejected, rejected);
    }

    #[test]
    fn least_loaded_dispatch_spreads_across_replicas() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 10)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 64,
                replicas: 4,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snap = router.metrics().snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            16
        );
        let used = snap.replicas.iter().filter(|r| r.requests > 0).count();
        assert!(used >= 2, "dispatch never spread: {:?}", snap.replicas);
        // Everything settled: no in-flight work left behind.
        assert!(snap.replicas.iter().all(|r| r.inflight == 0));
        assert!(snap.replicas.iter().all(|r| r.busy_us > 0
                || r.requests == 0));
    }

    #[test]
    fn captures_backend_shape_contract() {
        let router = Router::start(
            |_| {
                let mut b = MockBackend::with_shape(4, 0, (1, 28, 28), 26);
                b.labels = Some((b'a'..=b'z')
                    .map(|c| (c as char).to_string())
                    .collect());
                Ok(Box::new(b) as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        )
        .unwrap();
        assert_eq!(router.input_shape(), (1, 28, 28));
        assert_eq!(router.image_elems(), 28 * 28);
        assert_eq!(router.classes(), 26);
        assert_eq!(router.labels().map(<[String]>::len), Some(26));
        let reply = router.submit_wait(vec![0.9; 28 * 28]).unwrap();
        assert_eq!(reply.logits.len(), 26);
        router.shutdown();
    }

    #[test]
    fn wrong_shape_submit_is_typed_and_harmless() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::with_shape(4, 0, (2, 5, 7), 3))
                   as Box<dyn Backend>),
            RouterConfig { replicas: 1, ..RouterConfig::default() },
        )
        .unwrap();
        assert_eq!(
            router.submit(vec![0.0; 71]).err(),
            Some(SubmitError::WrongShape { expected: 70, got: 71 })
        );
        assert!(router.submit(Vec::new()).is_err());
        // The pool is untouched: a correct submit still round-trips.
        let reply = router.submit_wait(vec![0.5; 70]).unwrap();
        assert_eq!(reply.logits.len(), 3);
        assert_eq!(router.metrics().snapshot().completed, 1);
        router.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let r = router.submit_wait(image(0.1)).unwrap();
        assert_eq!(r.logits.len(), 10);
        router.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let metrics = router.metrics();
        router.shutdown();
        let _ = metrics.snapshot(); // metrics survive shutdown
    }

    #[test]
    fn factory_failure_on_any_replica_is_synchronous() {
        let r = Router::start(
            |replica| {
                if replica == 1 {
                    anyhow::bail!("replica 1 refused")
                }
                Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        );
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("refused"));
    }
}
