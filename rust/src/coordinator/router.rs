//! Router: bounded admission queue -> dynamic batcher -> replica pool.
//!
//! One [`Router`] drives a pool of `cfg.replicas` worker threads, each
//! holding its own [`Backend`] (for the native engine: one `Session`
//! minted per replica from one shared compiled `Plan` — see
//! [`super::backend::NativeBackend::from_plan`]).  Submission is
//! non-blocking with explicit backpressure (`SubmitError::QueueFull`
//! when the admission queue is at capacity); replies come back over
//! per-request channels.
//!
//! The pipeline:
//!
//! ```text
//!     submit -> bounded queue -> batcher thread -(least-loaded)->
//!         replica 0..N worker threads -> per-request reply channels
//! ```
//!
//! The batcher forms batches **continuously**
//! ([`super::batcher::ContinuousBatcher`]): under load — every replica
//! busy — an open batch keeps admitting queued requests right until
//! the instant a replica frees, then dispatches immediately; with idle
//! replicas it degrades to the classic max-size/max-delay policy.
//! Each batch goes to the replica with the fewest in-flight requests
//! (tracked in [`Metrics::replicas`]).  Per-replica dispatch channels
//! are bounded to one queued batch, so when every replica is saturated
//! the admission queue fills and callers see `QueueFull` —
//! backpressure is preserved end to end.  [`Router::shutdown`] drains: every accepted
//! request is batched, dispatched and answered before the threads are
//! joined.  A serving deployment maps model names to routers (see
//! `server/`).
//!
//! The router is **shape-generic**: at [`Router::start`] it captures
//! the backends' shape contract ([`Backend::input_shape`] /
//! [`Backend::classes`] / [`Backend::labels`]), validates every
//! [`Router::submit`] against it (wrong-sized images are a typed
//! [`SubmitError::WrongShape`], never a worker panic), and sizes each
//! replica's reusable padded batch tensor from it.  A single process
//! can therefore pool routers over heterogeneous models — a
//! 3x32x32/10-class CNN next to a 1x28x28/26-class fc net — with no
//! geometry hardwired anywhere on the request path.
//!
//! **Supervision.**  Each replica's batch execution runs under
//! `catch_unwind`: a panicking backend fails that batch's replies with
//! a typed [`ReplyError::ReplicaPanicked`] (a caller NEVER observes a
//! hung `recv()`), and the worker thread survives — it rebuilds its
//! backend from the shared factory with capped exponential backoff and
//! rejoins the rotation.  Every reply channel therefore resolves to
//! `Result<InferReply, ReplyError>`: `Ok` for a classification, a
//! typed error for a panic, a backend failure, a missed deadline, or
//! shutdown.  Restart counts are exported per replica
//! (`bitkernel_replica_restarts`), and while a replica is mid-respawn
//! the dispatcher deprioritizes it; with EVERY replica down the
//! router reports [`Router::circuit_open`], which the serving layer
//! maps to `503 + Retry-After`.
//!
//! **Deadlines.**  [`SubmitOptions::deadline`] rides with the request
//! through the queue and the batcher; a replica answers requests whose
//! deadline already passed with [`ReplyError::DeadlineExceeded`]
//! WITHOUT running inference, and
//! [`Router::submit_wait_deadline`] bounds the caller-side wait the
//! same way — an end-to-end latency contract, not a client-side timer.
//!
//! **Retiring a shared router.**  `Drop` runs the same drain as
//! [`Router::shutdown`], which makes `Arc<Router>` the hot-swap
//! primitive the model registry (`server/registry.rs`) builds on: the
//! registry publishes `Arc<Router>` handles, every in-flight request
//! holds a clone, and a reload/unmount simply swaps the published
//! handle and lets the *last* clone's drop drain the old
//! pipeline — accepted requests are answered by whichever generation
//! admitted them, and no request is ever dropped mid-swap.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::nn::argmax;

use super::backend::Backend;
use super::batcher::{BatchBuffer, BatcherConfig, ContinuousBatcher};
use super::metrics::Metrics;
use super::numa::{self, NumaNode, NumaPolicy};

/// A completed inference.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Argmax class index.
    pub class: usize,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Time from submit to batch formation.
    pub queue_us: u64,
    /// Time from submit to reply.
    pub total_us: u64,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue at capacity — caller should retry/shed.
    QueueFull,
    /// The image's element count does not match the model's input
    /// shape (`C*H*W` — see [`Router::input_shape`]).
    WrongShape {
        /// Elements the model's input shape requires.
        expected: usize,
        /// Elements the submission carried.
        got: usize,
    },
    /// Router shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::WrongShape { expected, got } => write!(
                f,
                "image has {got} elements, model expects {expected}"
            ),
            SubmitError::Shutdown => write!(f, "router shut down"),
        }
    }
}

/// Why an ACCEPTED request failed.  Every accepted request resolves —
/// with a reply or with one of these; a hung reply `recv()` is a bug
/// (pinned by `rust/tests/chaos.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplyError {
    /// The replica executing this request's batch panicked.  The
    /// replica respawns; the request does NOT auto-retry (it may have
    /// CAUSED the panic).
    ReplicaPanicked {
        /// True when this request was the only member of the panicked
        /// batch — i.e. it is individually identified as the poison
        /// and should be quarantined, not retried.
        quarantined: bool,
    },
    /// The backend returned an error (no panic; the replica keeps
    /// running with the same backend).
    BackendFailed(String),
    /// The request's deadline passed before a reply was produced; if
    /// it expired while still queued, inference was skipped entirely.
    DeadlineExceeded,
    /// The router shut down before answering.
    Shutdown,
}

impl std::fmt::Display for ReplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyError::ReplicaPanicked { quarantined: true } => {
                write!(f, "replica panicked; request quarantined")
            }
            ReplyError::ReplicaPanicked { quarantined: false } => {
                write!(f, "replica panicked while serving this batch")
            }
            ReplyError::BackendFailed(e) => {
                write!(f, "inference failed: {e}")
            }
            ReplyError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ReplyError::Shutdown => write!(f, "router shut down"),
        }
    }
}

/// Everything [`Router::submit_wait_deadline`] can fail with: the
/// submission was never accepted ([`RequestError::Rejected`]) or it
/// was accepted and then failed ([`RequestError::Failed`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// Not admitted — see [`SubmitError`]; nothing was queued.
    Rejected(SubmitError),
    /// Admitted but not answered with a reply — see [`ReplyError`].
    Failed(ReplyError),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Rejected(e) => write!(f, "{e}"),
            RequestError::Failed(e) => write!(f, "{e}"),
        }
    }
}

/// Per-request submission options.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// End-to-end deadline.  Rides with the request through the queue
    /// and the batcher: a replica answers an already-expired request
    /// with [`ReplyError::DeadlineExceeded`] WITHOUT running
    /// inference, and [`Router::submit_wait_deadline`] stops waiting
    /// at the same instant.  `None` waits indefinitely.
    pub deadline: Option<Instant>,
}

impl SubmitOptions {
    /// Options with a deadline of `timeout` from now.
    pub fn with_timeout(timeout: Duration) -> Self {
        Self { deadline: Some(Instant::now() + timeout) }
    }
}

/// How a request's answer travels back to its submitter.  The channel
/// arm serves the blocking front end (`submit_wait*` recv's on it);
/// the callback arm serves the event-loop front end, which cannot
/// block a reactor thread on a recv — the replica worker invokes the
/// callback directly when the batch resolves.  Either way the answer
/// is delivered from the same code paths, so supervision ("every
/// accepted request resolves, typed") covers both identically.
enum Responder {
    Channel(mpsc::Sender<Result<InferReply, ReplyError>>),
    Callback(Box<dyn FnOnce(Result<InferReply, ReplyError>) + Send>),
}

impl Responder {
    /// Deliver the answer.  A hung-up channel receiver is fine (the
    /// waiter gave up); a callback must not panic — it runs on a
    /// replica worker thread outside the `catch_unwind` fence.
    fn send(self, result: Result<InferReply, ReplyError>) {
        match self {
            Responder::Channel(tx) => {
                let _ = tx.send(result);
            }
            Responder::Callback(f) => f(result),
        }
    }
}

struct Request {
    /// Normalized CHW image (`C*H*W` f32, validated at submit).
    image: Vec<f32>,
    submitted: Instant,
    /// End-to-end deadline ([`SubmitOptions::deadline`]).
    deadline: Option<Instant>,
    responder: Responder,
}

/// A formed batch in flight from the batcher to a replica.
struct Batch {
    /// When the batcher closed the batch (queue-latency reference).
    formed: Instant,
    reqs: Vec<Request>,
}

/// A backend constructor, called once per replica (with the replica
/// index) inside that replica's worker thread — and again on the same
/// thread whenever a panicked replica respawns.
pub type BackendFactory =
    dyn Fn(usize) -> anyhow::Result<Box<dyn Backend>> + Send + Sync;

/// First delay between respawn attempts after a replica panic; doubles
/// per failed attempt up to [`RESPAWN_BACKOFF_CAP`].  A succeeding
/// factory (the common case — native backends share a compiled plan)
/// respawns on the first attempt with no sleep at all.
const RESPAWN_BACKOFF_BASE: Duration = Duration::from_millis(10);
/// Ceiling for the respawn backoff.  Also bounds how long a draining
/// router can wait on a replica stuck in backoff.
const RESPAWN_BACKOFF_CAP: Duration = Duration::from_secs(1);

/// Default replica count: one worker per core the host exposes, capped
/// at 8 (large gemm ops inside a native replica already fan out on the
/// plan's shared thread pool, so more replicas than cores only adds
/// contention).
pub fn default_replicas() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Worker replicas behind the batcher (>= 1).  Defaults to
    /// [`default_replicas`].
    pub replicas: usize,
    /// Batch-formation policy.
    pub batcher: BatcherConfig,
    /// NUMA placement for replica workers (`serve --numa`).  With
    /// [`NumaPolicy::RoundRobin`] each worker pins itself to one
    /// node's cores BEFORE constructing its backend and batch buffer,
    /// so first-touch places its hot pages on the node it will run on.
    pub numa_policy: NumaPolicy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            replicas: default_replicas(),
            batcher: BatcherConfig::default(),
            numa_policy: NumaPolicy::Off,
        }
    }
}

/// What a replica reports once its backend is constructed: the
/// metrics label plus the backend's full shape contract.
struct ReplicaInfo {
    name: String,
    cap: usize,
    shape: (usize, usize, usize),
    classes: usize,
    labels: Option<Vec<String>>,
}

/// A running pipeline: queue -> batcher -> replica pool.
pub struct Router {
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    backend_name: String,
    replicas: usize,
    /// Shape contract captured from the backends at startup.
    input_shape: (usize, usize, usize),
    classes: usize,
    labels: Option<Vec<String>>,
}

impl Router {
    /// Spawn the replica pool and batcher; the backends are constructed
    /// INSIDE their worker threads via `factory` (PJRT handles are not
    /// `Send`), called once per replica with the replica index.
    /// Construction errors on any replica are surfaced synchronously
    /// and tear the whole pool down.  The factory is retained for the
    /// router's lifetime: a replica that panics mid-batch rebuilds its
    /// backend through it (same thread, capped exponential backoff).
    ///
    /// For the native engine, compile the plan ONCE outside and let
    /// every call mint a session from it:
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, NativeBackend, Router,
    ///                              RouterConfig};
    /// use bitkernel::model::EngineKernel;
    /// use bitkernel::bitops::XnorImpl;
    ///
    /// let engine = bitkernel::testing::synthetic_engine(
    ///     [8, 8, 8, 8, 8, 8, 16, 16, 10], 1);
    /// let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4).unwrap();
    /// let router = Router::start(
    ///     move |_replica| {
    ///         Ok(Box::new(NativeBackend::from_plan(&plan))
    ///             as Box<dyn Backend>)
    ///     },
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// assert_eq!(router.replicas(), 2);
    /// router.shutdown();
    /// ```
    pub fn start<F>(factory: F, cfg: RouterConfig) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn Backend>>
            + Send
            + Sync
            + 'static,
    {
        assert!(cfg.replicas >= 1, "need at least one replica");
        let replicas = cfg.replicas;
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::with_replicas(replicas));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) =
            mpsc::channel::<anyhow::Result<ReplicaInfo>>();

        // NUMA topology is read once here; each worker gets its node
        // assignment up front (round-robin over the discovered nodes).
        // No topology — non-linux, hidden sysfs, single node with the
        // policy off — degrades to unpinned workers, never an error.
        let numa_nodes: Vec<NumaNode> = match cfg.numa_policy {
            NumaPolicy::Off => Vec::new(),
            NumaPolicy::RoundRobin => {
                let nodes = numa::nodes();
                if nodes.is_empty() {
                    crate::log_warn!(
                        "NUMA policy requested but no topology found; \
                         replicas run unpinned"
                    );
                }
                nodes
            }
        };

        // Per-replica dispatch channels are bounded to ONE queued batch:
        // enough to keep a replica busy back to back, small enough that
        // saturation propagates to the admission queue (backpressure).
        let mut workers = Vec::with_capacity(replicas);
        let mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>> =
            Vec::with_capacity(replicas);
        for r in 0..replicas {
            let (btx, brx) = mpsc::sync_channel::<Batch>(1);
            batch_txs.push(Some(btx));
            let f = Arc::clone(&factory);
            let m = Arc::clone(&metrics);
            let rtx = ready_tx.clone();
            let node = (!numa_nodes.is_empty())
                .then(|| numa_nodes[r % numa_nodes.len()].clone());
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bk-replica{r}"))
                    .spawn(move || {
                        replica_loop(r, &*f, brx, &m, rtx, node)
                    })
                    .expect("spawn replica worker"),
            );
        }
        drop(ready_tx);

        // Collect startup results; the smallest backend capacity bounds
        // batch formation so every batch fits every replica, and every
        // replica must publish the SAME shape contract (one factory,
        // one model — a mismatch is a backend bug surfaced here, not a
        // worker panic later).
        let mut backend_name = String::new();
        let mut min_cap = usize::MAX;
        let mut contract: Option<((usize, usize, usize), usize)> = None;
        let mut labels: Option<Vec<String>> = None;
        for _ in 0..replicas {
            let result = match ready_rx.recv() {
                Ok(r) => r,
                // A worker died without reporting (panicked in factory).
                Err(_) => Err(anyhow::anyhow!(
                    "replica worker died during startup"
                )),
            };
            let result = result.and_then(|info| {
                match contract {
                    None => contract = Some((info.shape, info.classes)),
                    Some(c) if c != (info.shape, info.classes) => {
                        anyhow::bail!(
                            "replica shape contracts disagree: \
                             {:?}/{} vs {:?}/{}",
                            c.0, c.1, info.shape, info.classes
                        )
                    }
                    Some(_) => {}
                }
                Ok(info)
            });
            match result {
                Ok(info) => {
                    backend_name = info.name;
                    min_cap = min_cap.min(info.cap);
                    if labels.is_none() {
                        labels = info.labels;
                    }
                }
                Err(e) => {
                    // Tear the pool down: dropping the dispatch channels
                    // ends every replica that did start.
                    drop(batch_txs);
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }
        let (input_shape, classes) =
            contract.expect("replicas >= 1 reported");

        let bcfg = BatcherConfig {
            // Never form batches larger than the smallest backend.
            max_batch: cfg.batcher.max_batch.min(min_cap),
            max_delay: cfg.batcher.max_delay,
        };
        let m = Arc::clone(&metrics);
        let batcher = std::thread::Builder::new()
            .name("bk-batcher".to_string())
            .spawn(move || batcher_loop(rx, bcfg, batch_txs, &m))
            .expect("spawn batcher");

        Ok(Self {
            tx: Some(tx),
            metrics,
            batcher: Some(batcher),
            workers,
            backend_name,
            replicas,
            input_shape,
            classes,
            labels,
        })
    }

    /// Label of the backend the pool runs (all replicas share one
    /// factory, hence one label).
    pub fn backend_name(&self) -> &str {
        &self.backend_name
    }

    /// Per-image input shape (C, H, W) this router's model expects —
    /// the shape contract captured from the backends at startup.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.input_shape
    }

    /// Elements of one request image (`C*H*W`) — the length
    /// [`Router::submit`] validates against.
    pub fn image_elems(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Number of output classes (reply logits have this length).
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// The model's class-label table, when it carries one
    /// (`labels()[c]` names class `c`).
    pub fn labels(&self) -> Option<&[String]> {
        self.labels.as_deref()
    }

    /// Display name for `class`: the label table's entry, or the
    /// numeric index for label-less models
    /// ([`crate::model::label_for`]).
    pub fn label_for(&self, class: usize) -> String {
        crate::model::label_for(self.labels(), class)
    }

    /// Number of worker replicas in the pool.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Replicas currently serving: the pool size minus replicas
    /// mid-respawn after a panic.  Converges back to
    /// [`Router::replicas`] once every respawn lands.
    pub fn healthy_replicas(&self) -> usize {
        let restarting: u64 = self
            .metrics
            .replicas
            .iter()
            .map(|r| r.restarting.load(Ordering::Relaxed))
            .sum();
        self.replicas.saturating_sub(restarting as usize)
    }

    /// Circuit breaker: true while EVERY replica is down mid-respawn.
    /// Submissions still enqueue (the pool recovers with backoff
    /// bounded by ~1s), but latency-sensitive callers — the HTTP layer
    /// maps this to `503 + Retry-After` — should shed instead.
    pub fn circuit_open(&self) -> bool {
        self.healthy_replicas() == 0
    }

    /// Shared handle to the router's counters.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Non-blocking submit; returns the reply channel.  The image must
    /// have exactly [`Router::image_elems`] elements (the model's
    /// `C*H*W`) — anything else is a typed
    /// [`SubmitError::WrongShape`], checked here at admission so a
    /// malformed request can never reach (let alone panic) a worker.
    /// The reply channel ALWAYS resolves for an accepted request:
    /// `Ok(reply)` or a typed [`ReplyError`] — never a hang.
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, MockBackend, Router,
    ///                              RouterConfig, SubmitError};
    ///
    /// let router = Router::start(
    ///     |_replica| Ok(Box::new(MockBackend::new(4, 0))
    ///                   as Box<dyn Backend>),
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// assert_eq!(router.input_shape(), (3, 32, 32));
    /// let rx = router.submit(vec![0.5; router.image_elems()]).unwrap();
    /// let reply = rx.recv().unwrap().unwrap();
    /// assert_eq!(reply.logits.len(), router.classes());
    /// assert!(matches!(router.submit(vec![0.5; 7]),
    ///                  Err(SubmitError::WrongShape { .. })));
    /// router.shutdown();
    /// ```
    pub fn submit(
        &self,
        image_chw: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<InferReply, ReplyError>>, SubmitError>
    {
        self.submit_with(image_chw, SubmitOptions::default())
    }

    /// [`Router::submit`] with per-request [`SubmitOptions`] (deadline).
    pub fn submit_with(
        &self,
        image_chw: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<Result<InferReply, ReplyError>>, SubmitError>
    {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.enqueue(image_chw, opts, Responder::Channel(reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::submit_with`], answered by invoking `reply` instead
    /// of a channel — the submission path for the event-loop front
    /// end, whose reactor threads must never block on a reply recv.
    ///
    /// Same admission contract as [`Router::submit`] (shape
    /// validation, `QueueFull` backpressure), and the same resolution
    /// guarantee: once this returns `Ok`, `reply` WILL be invoked
    /// exactly once — with a reply or a typed [`ReplyError`] — from a
    /// replica worker (or drain path) thread.  The callback must be
    /// cheap and panic-free; it runs on the serving hot path.
    ///
    /// Note the caller-side difference from
    /// [`Router::submit_wait_deadline`]: an expired deadline is still
    /// answered typed ([`ReplyError::DeadlineExceeded`]), but delivery
    /// happens when the pipeline reaches the request, not at the
    /// deadline instant itself.
    pub fn submit_callback(
        &self,
        image_chw: Vec<f32>,
        opts: SubmitOptions,
        reply: impl FnOnce(Result<InferReply, ReplyError>) + Send + 'static,
    ) -> Result<(), SubmitError> {
        self.enqueue(image_chw, opts, Responder::Callback(Box::new(reply)))
    }

    fn enqueue(
        &self,
        image_chw: Vec<f32>,
        opts: SubmitOptions,
        responder: Responder,
    ) -> Result<(), SubmitError> {
        let expected = self.image_elems();
        if image_chw.len() != expected {
            return Err(SubmitError::WrongShape {
                expected,
                got: image_chw.len(),
            });
        }
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let req = Request {
            image: image_chw,
            submitted: Instant::now(),
            deadline: opts.deadline,
            responder,
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Submit and block for the reply (no deadline).
    pub fn submit_wait(
        &self,
        image_chw: Vec<f32>,
    ) -> Result<InferReply, RequestError> {
        self.submit_wait_deadline(image_chw, SubmitOptions::default())
    }

    /// Submit and block for the reply, bounded by `opts.deadline`: the
    /// request carries the deadline through the pipeline (an expired
    /// request is answered without running inference) AND the wait
    /// itself stops at the deadline with
    /// [`ReplyError::DeadlineExceeded`] — the end-to-end contract
    /// behind `/classify?timeout_ms=`.
    pub fn submit_wait_deadline(
        &self,
        image_chw: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<InferReply, RequestError> {
        let rx = self
            .submit_with(image_chw, opts)
            .map_err(RequestError::Rejected)?;
        let reply = match opts.deadline {
            None => rx.recv().map_err(|_| {
                RequestError::Failed(ReplyError::Shutdown)
            })?,
            Some(deadline) => {
                let remaining =
                    deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        return Err(RequestError::Failed(
                            ReplyError::DeadlineExceeded,
                        ))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(RequestError::Failed(
                            ReplyError::Shutdown,
                        ))
                    }
                }
            }
        };
        reply.map_err(RequestError::Failed)
    }

    /// Graceful drain: stop admissions, let the batcher flush every
    /// queued request through the replicas, then join all threads.  No
    /// accepted request is dropped.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        drop(self.tx.take());
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.drain();
    }
}

/// One replica worker: construct the backend, report readiness, then
/// execute dispatched batches until the batcher hangs up.  A panic
/// inside batch execution does NOT kill the worker: the batch's
/// replies fail typed and the backend is rebuilt from the factory
/// ([`respawn`]) before the next batch.
fn replica_loop(
    replica: usize,
    factory: &BackendFactory,
    brx: mpsc::Receiver<Batch>,
    m: &Metrics,
    ready_tx: mpsc::Sender<anyhow::Result<ReplicaInfo>>,
    node: Option<NumaNode>,
) {
    // Pin BEFORE constructing anything: the backend's session scratch
    // and the batch buffer below are first-touched — hence physically
    // placed — by this thread, so pinning first makes every hot page
    // node-local.  Respawns rebuild on this same pinned thread, so
    // placement survives supervision.  A failed pin (shrunk cgroup
    // cpuset, exotic kernel) degrades to unpinned, never to a dead
    // replica.
    if let Some(node) = &node {
        match numa::pin_current_thread(&node.cpus) {
            Ok(()) => {
                m.replicas[replica]
                    .numa_node
                    .store(node.id as u64, Ordering::Relaxed);
                crate::log_info!(
                    "replica {replica} pinned to NUMA node {} \
                     ({} cpus)",
                    node.id,
                    node.cpus.len()
                );
            }
            Err(e) => crate::log_warn!(
                "replica {replica}: pin to NUMA node {} failed: {e}; \
                 running unpinned",
                node.id
            ),
        }
    }
    let mut backend = match factory(replica) {
        Ok(b) => {
            let _ = ready_tx.send(Ok(ReplicaInfo {
                name: b.name().to_string(),
                cap: b.max_batch(),
                shape: b.input_shape(),
                classes: b.classes(),
                labels: b.labels().map(<[String]>::to_vec),
            }));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);
    // The replica's reusable padded input tensor, sized from the
    // backend's shape contract — refilled in place per batch, so the
    // dispatch hot path allocates nothing for image data.
    let mut buffer =
        BatchBuffer::new(backend.max_batch(), backend.input_shape());
    let mut batch_seq: u64 = 0;
    while let Ok(batch) = brx.recv() {
        batch_seq += 1;
        let poisoned =
            run_batch(&mut *backend, &mut buffer, batch, replica,
                      batch_seq, m);
        if poisoned {
            match respawn(replica, factory, &brx, m) {
                Some(b) => {
                    buffer = BatchBuffer::new(
                        b.max_batch(),
                        b.input_shape(),
                    );
                    backend = b;
                }
                // The router is draining; nothing left to serve.
                None => return,
            }
        }
    }
}

/// Execute one dispatched batch on `backend`.  Expired requests are
/// answered [`ReplyError::DeadlineExceeded`] without inference; the
/// rest run under `catch_unwind` so a panicking backend fails its
/// replies typed instead of hanging them.  Returns `true` when the
/// panic poisoned the backend (the caller must respawn it).
fn run_batch(
    backend: &mut dyn Backend,
    buffer: &mut BatchBuffer,
    batch: Batch,
    replica: usize,
    batch_seq: u64,
    m: &Metrics,
) -> bool {
    let rm = &m.replicas[replica];
    let Batch { formed, reqs } = batch;
    let total = reqs.len() as u64;
    // Deadline gate: a request already past its deadline is answered
    // typed here, before any inference work happens on its behalf.
    let now = Instant::now();
    let (live, expired): (Vec<Request>, Vec<Request>) = reqs
        .into_iter()
        .partition(|r| !r.deadline.is_some_and(|d| now >= d));
    if !expired.is_empty() {
        m.deadline_expired
            .fetch_add(expired.len() as u64, Ordering::Relaxed);
        for r in expired {
            r.responder.send(Err(ReplyError::DeadlineExceeded));
        }
    }
    if live.is_empty() {
        rm.inflight.fetch_sub(total, Ordering::Relaxed);
        return false;
    }
    let b = live.len();
    let infer_sw = Instant::now();
    // AssertUnwindSafe: on panic both `backend` and `buffer` are
    // discarded and rebuilt by the caller, so any state a panic left
    // half-written is never observed.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<Vec<Vec<f32>>> {
            crate::testing::chaos::before_infer(replica, batch_seq);
            let images = buffer.fill(live.iter().map(|r| &r.image[..]));
            let logits = backend.infer(images)?;
            Ok((0..b).map(|i| logits.row(i).to_vec()).collect())
        },
    ));
    let infer_us = infer_sw.elapsed().as_micros() as u64;
    rm.batches.fetch_add(1, Ordering::Relaxed);
    rm.requests.fetch_add(b as u64, Ordering::Relaxed);
    rm.busy_us.fetch_add(infer_us, Ordering::Relaxed);
    rm.infer_latency.record_us(infer_us);
    let poisoned = match outcome {
        Ok(Ok(rows)) => {
            let done = Instant::now();
            for (r, row) in live.into_iter().zip(rows) {
                let reply = InferReply {
                    class: argmax(&row),
                    logits: row,
                    queue_us: (formed - r.submitted).as_micros() as u64,
                    total_us: (done - r.submitted).as_micros() as u64,
                };
                m.total_latency.record_us(reply.total_us);
                m.completed.fetch_add(1, Ordering::Relaxed);
                r.responder.send(Ok(reply));
            }
            false
        }
        Ok(Err(e)) => {
            crate::log_error!(
                "replica {replica} inference failed: {e:#}"
            );
            m.rejected.fetch_add(b as u64, Ordering::Relaxed);
            let msg = format!("{e:#}");
            for r in live {
                r.responder
                    .send(Err(ReplyError::BackendFailed(msg.clone())));
            }
            false
        }
        Err(_) => {
            // With exactly one request in the panicked batch, that
            // request IS the identified poison: mark it quarantined so
            // callers know not to retry it.
            let quarantined = b == 1;
            crate::log_error!(
                "replica {replica} panicked on batch {batch_seq} \
                 ({b} requests); respawning"
            );
            m.panics.fetch_add(1, Ordering::Relaxed);
            if quarantined {
                m.quarantined.fetch_add(1, Ordering::Relaxed);
            }
            m.rejected.fetch_add(b as u64, Ordering::Relaxed);
            for r in live {
                r.responder
                    .send(Err(ReplyError::ReplicaPanicked { quarantined }));
            }
            true
        }
    };
    rm.inflight.fetch_sub(total, Ordering::Relaxed);
    poisoned
}

/// Rebuild a panicked replica's backend from the shared factory with
/// capped exponential backoff ([`RESPAWN_BACKOFF_BASE`] doubling up to
/// [`RESPAWN_BACKOFF_CAP`]).  Batches dispatched while the replica is
/// down are answered typed (never left hanging) between attempts.
/// Returns `None` when the router started draining (dispatch channel
/// disconnected) — the worker should exit instead of respawning.
fn respawn(
    replica: usize,
    factory: &BackendFactory,
    brx: &mpsc::Receiver<Batch>,
    m: &Metrics,
) -> Option<Box<dyn Backend>> {
    let rm = &m.replicas[replica];
    rm.restarting.store(1, Ordering::Relaxed);
    let mut delay = RESPAWN_BACKOFF_BASE;
    loop {
        // Fail over anything queued on this replica while it is down.
        loop {
            match brx.try_recv() {
                Ok(batch) => fail_batch(batch, replica, m),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    rm.restarting.store(0, Ordering::Relaxed);
                    return None;
                }
            }
        }
        // The factory may itself fail or panic (e.g. injected
        // weight-read faults) — stay in the backoff loop.
        let attempt = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| factory(replica)),
        );
        match attempt {
            Ok(Ok(backend)) => {
                rm.restarts.fetch_add(1, Ordering::Relaxed);
                rm.restarting.store(0, Ordering::Relaxed);
                crate::log_info!("replica {replica} respawned");
                return Some(backend);
            }
            Ok(Err(e)) => {
                crate::log_error!(
                    "replica {replica} respawn failed: {e:#}; \
                     retrying in {delay:?}"
                );
            }
            Err(_) => {
                crate::log_error!(
                    "replica {replica} factory panicked during \
                     respawn; retrying in {delay:?}"
                );
            }
        }
        std::thread::sleep(delay);
        delay = (delay * 2).min(RESPAWN_BACKOFF_CAP);
    }
}

/// Answer every request of a batch dispatched to a down replica with a
/// typed error (and release its in-flight accounting).
fn fail_batch(batch: Batch, replica: usize, m: &Metrics) {
    let rm = &m.replicas[replica];
    let n = batch.reqs.len() as u64;
    m.rejected.fetch_add(n, Ordering::Relaxed);
    for r in batch.reqs {
        r.responder
            .send(Err(ReplyError::ReplicaPanicked { quarantined: false }));
    }
    rm.inflight.fetch_sub(n, Ordering::Relaxed);
}

/// The batcher thread: form batches continuously, dispatch each to the
/// least-loaded replica.  Exits (dropping the dispatch channels, which
/// drains the workers) when every submitter hung up and the queue is
/// empty.
///
/// The continuous policy needs a replica-availability probe: a replica
/// counts as free when it is alive (dispatch slot not retired), not
/// mid-respawn, and has NOTHING in flight — its slot is empty and its
/// backend idle, so a batch handed to it starts executing immediately.
fn batcher_loop(
    rx: mpsc::Receiver<Request>,
    bcfg: BatcherConfig,
    mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>>,
    m: &Metrics,
) {
    let mut batcher = ContinuousBatcher::new(rx, bcfg);
    loop {
        let alive: Vec<bool> =
            batch_txs.iter().map(Option::is_some).collect();
        let free = || {
            alive.iter().enumerate().any(|(r, &ok)| {
                let rm = &m.replicas[r];
                ok && rm.restarting.load(Ordering::Relaxed) == 0
                    && rm.inflight.load(Ordering::Relaxed) == 0
            })
        };
        let Some(reqs) = batcher.next_batch(free) else { break };
        let formed = Instant::now();
        let b = reqs.len();
        m.batches.fetch_add(1, Ordering::Relaxed);
        m.batched_requests.fetch_add(b as u64, Ordering::Relaxed);
        for r in &reqs {
            m.queue_latency
                .record_us((formed - r.submitted).as_micros() as u64);
        }
        dispatch(Batch { formed, reqs }, &mut batch_txs, m);
    }
}

/// Least-loaded dispatch: try replicas in ascending (restarting,
/// in-flight) order without blocking — a replica mid-respawn sorts
/// last, so batches prefer healthy workers; if every dispatch slot is
/// full, block on the best-ranked live replica (which stalls the
/// batcher and, in turn, fills the admission queue — the backpressure
/// path).  Replicas whose worker died are retired from the rotation.
fn dispatch(
    mut batch: Batch,
    batch_txs: &mut [Option<mpsc::SyncSender<Batch>>],
    m: &Metrics,
) {
    let b = batch.reqs.len() as u64;
    loop {
        let mut order: Vec<usize> = (0..batch_txs.len())
            .filter(|&r| batch_txs[r].is_some())
            .collect();
        if order.is_empty() {
            // Every replica died: shed the batch typed (the supervised
            // loop makes this unreachable in practice, but a dropped
            // reply channel must never be the failure mode).
            m.rejected.fetch_add(b, Ordering::Relaxed);
            for r in batch.reqs {
                r.responder.send(Err(ReplyError::Shutdown));
            }
            return;
        }
        order.sort_by_key(|&r| {
            let rm = &m.replicas[r];
            (
                rm.restarting.load(Ordering::Relaxed),
                rm.inflight.load(Ordering::Relaxed),
            )
        });
        // Pass 1: non-blocking, in load order.
        for &r in &order {
            let rm = &m.replicas[r];
            rm.inflight.fetch_add(b, Ordering::Relaxed);
            match batch_txs[r].as_ref().unwrap().try_send(batch) {
                Ok(()) => return,
                Err(mpsc::TrySendError::Full(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch = back;
                }
                Err(mpsc::TrySendError::Disconnected(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch_txs[r] = None;
                    batch = back;
                }
            }
        }
        // Pass 2: every slot full — block on the best-ranked replica.
        // A restarting replica still consumes its slot between respawn
        // attempts (answering typed), so this cannot hang forever.
        let r = order[0];
        if batch_txs[r].is_none() {
            continue; // retired during pass 1; recompute the order
        }
        let rm = &m.replicas[r];
        rm.inflight.fetch_add(b, Ordering::Relaxed);
        match batch_txs[r].as_ref().unwrap().send(batch) {
            Ok(()) => return,
            Err(mpsc::SendError(back)) => {
                rm.inflight.fetch_sub(b, Ordering::Relaxed);
                batch_txs[r] = None;
                batch = back;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use crate::tensor::Tensor;
    use std::sync::atomic::{AtomicBool, AtomicUsize};
    use std::time::Duration;

    fn image(v: f32) -> Vec<f32> {
        vec![v; 3 * 32 * 32]
    }

    #[test]
    fn submit_roundtrip() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let reply = router.submit_wait(image(0.9)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        assert!(reply.class >= 8, "{}", reply.class); // high mean -> high class
        assert!(reply.total_us >= reply.queue_us);
        let snap = router.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.replicas.len(), router.replicas());
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            1
        );
    }

    #[test]
    fn batches_multiple_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_calls(
                    8,
                    5,
                    Arc::clone(&calls2),
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 64,
                replicas: 1, // a single replica pins the batch count
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(50),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // All 8 should have ridden one or two batches, not 8 singles.
        let n = calls.load(Ordering::SeqCst);
        assert!(n <= 2, "backend called {n} times");
        assert!(router.metrics().snapshot().mean_batch_size >= 4.0);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow backend + tiny queue -> QueueFull.
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 50)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 2,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let mut rejected = 0;
        let mut kept = Vec::new();
        for _ in 0..20 {
            match router.submit(image(0.0)) {
                Ok(rx) => kept.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "expected rejections");
        for rx in kept {
            let _ = rx.recv();
        }
        assert_eq!(router.metrics().snapshot().rejected, rejected);
    }

    #[test]
    fn least_loaded_dispatch_spreads_across_replicas() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 10)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 64,
                replicas: 4,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = router.metrics().snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            16
        );
        let used = snap.replicas.iter().filter(|r| r.requests > 0).count();
        assert!(used >= 2, "dispatch never spread: {:?}", snap.replicas);
        // Everything settled: no in-flight work left behind.
        assert!(snap.replicas.iter().all(|r| r.inflight == 0));
        assert!(snap.replicas.iter().all(|r| r.busy_us > 0
                || r.requests == 0));
    }

    #[test]
    fn captures_backend_shape_contract() {
        let router = Router::start(
            |_| {
                let mut b = MockBackend::with_shape(4, 0, (1, 28, 28), 26);
                b.labels = Some((b'a'..=b'z')
                    .map(|c| (c as char).to_string())
                    .collect());
                Ok(Box::new(b) as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        )
        .unwrap();
        assert_eq!(router.input_shape(), (1, 28, 28));
        assert_eq!(router.image_elems(), 28 * 28);
        assert_eq!(router.classes(), 26);
        assert_eq!(router.labels().map(<[String]>::len), Some(26));
        let reply = router.submit_wait(vec![0.9; 28 * 28]).unwrap();
        assert_eq!(reply.logits.len(), 26);
        router.shutdown();
    }

    #[test]
    fn wrong_shape_submit_is_typed_and_harmless() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::with_shape(4, 0, (2, 5, 7), 3))
                   as Box<dyn Backend>),
            RouterConfig { replicas: 1, ..RouterConfig::default() },
        )
        .unwrap();
        assert_eq!(
            router.submit(vec![0.0; 71]).err(),
            Some(SubmitError::WrongShape { expected: 70, got: 71 })
        );
        assert!(router.submit(Vec::new()).is_err());
        // The pool is untouched: a correct submit still round-trips.
        let reply = router.submit_wait(vec![0.5; 70]).unwrap();
        assert_eq!(reply.logits.len(), 3);
        assert_eq!(router.metrics().snapshot().completed, 1);
        router.shutdown();
    }

    #[test]
    fn numa_round_robin_starts_serves_and_labels() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig {
                replicas: 2,
                numa_policy: NumaPolicy::RoundRobin,
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let reply = router.submit_wait(image(0.5)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        let snap = router.metrics().snapshot();
        let nodes = numa::nodes();
        if nodes.is_empty() {
            // No topology (non-linux, hidden sysfs): policy degrades
            // to unpinned, never an error.
            assert!(snap.replicas.iter().all(|r| r.numa_node.is_none()));
        } else {
            for (r, rs) in snap.replicas.iter().enumerate() {
                // A pin can fail under restricted cgroup cpusets (the
                // worker then runs unpinned); when it lands, the label
                // must be the round-robin assignment.
                if let Some(n) = rs.numa_node {
                    assert_eq!(n, nodes[r % nodes.len()].id as u64);
                }
            }
        }
        router.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let r = router.submit_wait(image(0.1)).unwrap();
        assert_eq!(r.logits.len(), 10);
        router.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let metrics = router.metrics();
        router.shutdown();
        let _ = metrics.snapshot(); // metrics survive shutdown
    }

    #[test]
    fn factory_failure_on_any_replica_is_synchronous() {
        let r = Router::start(
            |replica| {
                if replica == 1 {
                    anyhow::bail!("replica 1 refused")
                }
                Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        );
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("refused"));
    }

    /// A backend that panics on `infer` while `armed` is set, else
    /// delegates to a [`MockBackend`] — the unit-level stand-in for
    /// the chaos harness (`testing::chaos` drives the integration
    /// suite in `rust/tests/chaos.rs`).
    struct PanicBackend {
        inner: MockBackend,
        armed: Arc<AtomicBool>,
    }

    impl Backend for PanicBackend {
        fn name(&self) -> &str {
            self.inner.name()
        }
        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
        fn input_shape(&self) -> (usize, usize, usize) {
            self.inner.input_shape()
        }
        fn classes(&self) -> usize {
            self.inner.classes()
        }
        fn infer(&mut self, images: &Tensor) -> anyhow::Result<&Tensor> {
            if self.armed.swap(false, Ordering::SeqCst) {
                panic!("injected test panic");
            }
            self.inner.infer(images)
        }
    }

    #[test]
    fn panicking_replica_replies_typed_and_respawns() {
        let armed = Arc::new(AtomicBool::new(true));
        let armed2 = Arc::clone(&armed);
        let router = Router::start(
            move |_| {
                Ok(Box::new(PanicBackend {
                    inner: MockBackend::new(4, 0),
                    armed: Arc::clone(&armed2),
                }) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 16,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        // First request rides the armed batch: typed panic error, and
        // as the sole batch member it is quarantined.
        let err = router.submit_wait(image(0.2)).unwrap_err();
        assert_eq!(
            err,
            RequestError::Failed(ReplyError::ReplicaPanicked {
                quarantined: true
            })
        );
        // The worker survived and respawned: the next request succeeds
        // on the SAME replica thread.
        let reply = router.submit_wait(image(0.9)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        let snap = router.metrics().snapshot();
        assert_eq!(snap.panics, 1);
        assert_eq!(snap.quarantined, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(
            snap.replicas.iter().map(|r| r.restarts).sum::<u64>(),
            1
        );
        assert_eq!(router.healthy_replicas(), 1);
        assert!(!router.circuit_open());
        router.shutdown();
    }

    #[test]
    fn expired_requests_skip_inference() {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_calls(
                    1,
                    10,
                    Arc::clone(&calls2),
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 16,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        // An already-expired deadline: the replica answers typed
        // without calling the backend.
        let rx = router
            .submit_with(
                image(0.0),
                SubmitOptions { deadline: Some(Instant::now()) },
            )
            .unwrap();
        assert!(matches!(
            rx.recv().unwrap(),
            Err(ReplyError::DeadlineExceeded)
        ));
        assert_eq!(calls.load(Ordering::SeqCst), 0);
        assert_eq!(router.metrics().snapshot().deadline_expired, 1);
        // A live deadline still classifies.
        let reply = router
            .submit_wait_deadline(
                image(0.5),
                SubmitOptions::with_timeout(Duration::from_secs(10)),
            )
            .unwrap();
        assert_eq!(reply.logits.len(), 10);
        router.shutdown();
    }

    #[test]
    fn submit_callback_resolves_without_a_channel() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        router
            .submit_callback(
                image(0.9),
                SubmitOptions::default(),
                move |r| tx.send(r).unwrap(),
            )
            .unwrap();
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits.len(), 10);
        // Wrong shape is rejected synchronously; the callback is
        // never invoked.
        let res = router.submit_callback(
            vec![0.0; 7],
            SubmitOptions::default(),
            |_| panic!("must not be called"),
        );
        assert!(matches!(res, Err(SubmitError::WrongShape { .. })));
        // An expired deadline resolves the callback typed.
        let (tx, rx) = mpsc::channel();
        router
            .submit_callback(
                image(0.1),
                SubmitOptions { deadline: Some(Instant::now()) },
                move |r| tx.send(r).unwrap(),
            )
            .unwrap();
        assert!(matches!(
            rx.recv().unwrap(),
            Err(ReplyError::DeadlineExceeded)
        ));
        router.shutdown();
    }

    #[test]
    fn submit_wait_deadline_bounds_the_wait() {
        // Slow backend, short deadline: the caller is released at the
        // deadline with a typed error — no hung recv.
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 200)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 16,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let err = router
            .submit_wait_deadline(
                image(0.0),
                SubmitOptions::with_timeout(Duration::from_millis(20)),
            )
            .unwrap_err();
        assert_eq!(
            err,
            RequestError::Failed(ReplyError::DeadlineExceeded)
        );
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "waited past the deadline: {:?}",
            t0.elapsed()
        );
        router.shutdown();
    }
}
