//! Router: bounded admission queue -> dynamic batcher -> backend worker.
//!
//! One [`Router`] drives one backend on a dedicated thread.  Submission
//! is non-blocking with explicit backpressure (`SubmitError::QueueFull`
//! when the admission queue is at capacity); replies come back over
//! per-request channels.  A serving deployment maps model names to
//! routers (see `server/`).

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::nn::argmax;
use crate::tensor::Tensor;

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;

pub const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// A completed inference.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub class: usize,
    pub logits: Vec<f32>,
    /// Time from submit to batch formation.
    pub queue_us: u64,
    /// Time from submit to reply.
    pub total_us: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue at capacity — caller should retry/shed.
    QueueFull,
    /// Router shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::Shutdown => write!(f, "router shut down"),
        }
    }
}

struct Request {
    /// Normalized CHW image (3*32*32 f32).
    image: Vec<f32>,
    submitted: Instant,
    reply_tx: mpsc::Sender<InferReply>,
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    pub batcher: BatcherConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { queue_cap: 256, batcher: BatcherConfig::default() }
    }
}

/// A running pipeline: queue -> batcher -> backend.
pub struct Router {
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
    backend_name: String,
}

impl Router {
    /// Spawn the worker thread; the backend is constructed INSIDE it via
    /// `factory` (PJRT handles are not `Send`).  Construction errors are
    /// surfaced synchronously.
    pub fn start<F>(factory: F, cfg: RouterConfig) -> anyhow::Result<Self>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::default());
        let m = Arc::clone(&metrics);
        let (ready_tx, ready_rx) =
            mpsc::channel::<anyhow::Result<(String, usize)>>();
        let batcher_cfg = cfg.batcher;
        let worker = std::thread::Builder::new()
            .name("bk-worker".to_string())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx
                            .send(Ok((b.name().to_string(), b.max_batch())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let bcfg = BatcherConfig {
                    // Never form batches larger than the backend.
                    max_batch: batcher_cfg.max_batch.min(backend.max_batch()),
                    max_delay: batcher_cfg.max_delay,
                };
                let batcher = DynamicBatcher::new(rx, bcfg);
                let cap = backend.max_batch();
                while let Some(batch) = batcher.next_batch() {
                    let formed = Instant::now();
                    let b = batch.len();
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.batched_requests.fetch_add(b as u64, Ordering::Relaxed);
                    for r in &batch {
                        m.queue_latency.record_us(
                            (formed - r.submitted).as_micros() as u64,
                        );
                    }
                    // Assemble the (padded) image tensor.
                    let mut data = vec![0.0f32; cap * IMAGE_ELEMS];
                    for (i, r) in batch.iter().enumerate() {
                        data[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS]
                            .copy_from_slice(&r.image);
                    }
                    let images =
                        Tensor::new(vec![cap, 3, 32, 32], data);
                    match backend.infer(&images) {
                        Ok(logits) => {
                            let done = Instant::now();
                            for (i, r) in batch.into_iter().enumerate() {
                                let row = logits.row(i).to_vec();
                                let reply = InferReply {
                                    class: argmax(&row),
                                    logits: row,
                                    queue_us: (formed - r.submitted)
                                        .as_micros()
                                        as u64,
                                    total_us: (done - r.submitted)
                                        .as_micros()
                                        as u64,
                                };
                                m.total_latency
                                    .record_us(reply.total_us);
                                m.completed.fetch_add(1, Ordering::Relaxed);
                                let _ = r.reply_tx.send(reply);
                            }
                        }
                        Err(e) => {
                            crate::log_error!(
                                "backend inference failed: {e:#}"
                            );
                            // Drop the requests; their reply channels
                            // disconnect, which callers observe as an
                            // error.
                            m.rejected
                                .fetch_add(b as u64, Ordering::Relaxed);
                        }
                    }
                }
            })
            .expect("spawn worker");
        let (backend_name, _max_batch) = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))??;
        Ok(Self { tx: Some(tx), metrics, worker: Some(worker), backend_name })
    }

    pub fn backend_name(&self) -> &str {
        &self.backend_name
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Non-blocking submit; returns the reply channel.
    pub fn submit(
        &self,
        image_chw: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        assert_eq!(image_chw.len(), IMAGE_ELEMS, "image element count");
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            image: image_chw,
            submitted: Instant::now(),
            reply_tx,
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Submit and block for the reply.
    pub fn submit_wait(&self, image_chw: Vec<f32>) -> Result<InferReply, SubmitError> {
        let rx = self.submit(image_chw)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Graceful shutdown: drain the queue, then join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use std::time::Duration;

    fn image(v: f32) -> Vec<f32> {
        vec![v; IMAGE_ELEMS]
    }

    #[test]
    fn submit_roundtrip() {
        let router = Router::start(
            || Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let reply = router.submit_wait(image(0.9)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        assert!(reply.class >= 8, "{}", reply.class); // high mean -> high class
        assert!(reply.total_us >= reply.queue_us);
        let snap = router.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn batches_multiple_requests() {
        let backend = MockBackend::new(8, 5);
        let calls = Arc::clone(&backend.calls);
        let router = Router::start(
            move || Ok(Box::new(backend) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 64,
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(50),
                },
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // All 8 should have ridden one or two batches, not 8 singles.
        let n = calls.load(Ordering::SeqCst);
        assert!(n <= 2, "backend called {n} times");
        assert!(router.metrics().snapshot().mean_batch_size >= 4.0);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow backend + tiny queue -> QueueFull.
        let router = Router::start(
            || Ok(Box::new(MockBackend::new(1, 50)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 2,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let mut rejected = 0;
        let mut kept = Vec::new();
        for _ in 0..20 {
            match router.submit(image(0.0)) {
                Ok(rx) => kept.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "expected rejections");
        for rx in kept {
            let _ = rx.recv();
        }
        assert_eq!(router.metrics().snapshot().rejected, rejected);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(
            || Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let r = router.submit_wait(image(0.1)).unwrap();
        assert_eq!(r.logits.len(), 10);
        router.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let router = Router::start(
            || Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let metrics = router.metrics();
        router.shutdown();
        let _ = metrics.snapshot(); // metrics survive shutdown
    }
}
