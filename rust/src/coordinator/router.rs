//! Router: bounded admission queue -> dynamic batcher -> replica pool.
//!
//! One [`Router`] drives a pool of `cfg.replicas` worker threads, each
//! holding its own [`Backend`] (for the native engine: one `Session`
//! minted per replica from one shared compiled `Plan` — see
//! [`super::backend::NativeBackend::from_plan`]).  Submission is
//! non-blocking with explicit backpressure (`SubmitError::QueueFull`
//! when the admission queue is at capacity); replies come back over
//! per-request channels.
//!
//! The pipeline:
//!
//! ```text
//!     submit -> bounded queue -> batcher thread -(least-loaded)->
//!         replica 0..N worker threads -> per-request reply channels
//! ```
//!
//! The batcher forms max-size/max-delay batches and hands each one to
//! the replica with the fewest in-flight requests (tracked in
//! [`Metrics::replicas`]).  Per-replica dispatch channels are bounded
//! to one queued batch, so when every replica is saturated the
//! admission queue fills and callers see `QueueFull` — backpressure is
//! preserved end to end.  [`Router::shutdown`] drains: every accepted
//! request is batched, dispatched and answered before the threads are
//! joined.  A serving deployment maps model names to routers (see
//! `server/`).

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::nn::argmax;
use crate::tensor::Tensor;

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;

/// Elements of one normalized CHW request image (3 * 32 * 32).
pub const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// A completed inference.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Argmax class index.
    pub class: usize,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Time from submit to batch formation.
    pub queue_us: u64,
    /// Time from submit to reply.
    pub total_us: u64,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue at capacity — caller should retry/shed.
    QueueFull,
    /// Router shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::Shutdown => write!(f, "router shut down"),
        }
    }
}

struct Request {
    /// Normalized CHW image (3*32*32 f32).
    image: Vec<f32>,
    submitted: Instant,
    reply_tx: mpsc::Sender<InferReply>,
}

/// A formed batch in flight from the batcher to a replica.
struct Batch {
    /// When the batcher closed the batch (queue-latency reference).
    formed: Instant,
    reqs: Vec<Request>,
}

/// A backend constructor, called once per replica (with the replica
/// index) inside that replica's worker thread.
pub type BackendFactory =
    dyn Fn(usize) -> anyhow::Result<Box<dyn Backend>> + Send + Sync;

/// Default replica count: one worker per core the host exposes, capped
/// at 8 (large gemm ops inside a native replica already fan out on the
/// plan's shared thread pool, so more replicas than cores only adds
/// contention).
pub fn default_replicas() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Worker replicas behind the batcher (>= 1).  Defaults to
    /// [`default_replicas`].
    pub replicas: usize,
    /// Batch-formation policy.
    pub batcher: BatcherConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            replicas: default_replicas(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// A running pipeline: queue -> batcher -> replica pool.
pub struct Router {
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    backend_name: String,
    replicas: usize,
}

impl Router {
    /// Spawn the replica pool and batcher; the backends are constructed
    /// INSIDE their worker threads via `factory` (PJRT handles are not
    /// `Send`), called once per replica with the replica index.
    /// Construction errors on any replica are surfaced synchronously
    /// and tear the whole pool down.
    ///
    /// For the native engine, compile the plan ONCE outside and let
    /// every call mint a session from it:
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, NativeBackend, Router,
    ///                              RouterConfig};
    /// use bitkernel::model::EngineKernel;
    /// use bitkernel::bitops::XnorImpl;
    ///
    /// let engine = bitkernel::testing::synthetic_engine(
    ///     [8, 8, 8, 8, 8, 8, 16, 16, 10], 1);
    /// let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4).unwrap();
    /// let router = Router::start(
    ///     move |_replica| {
    ///         Ok(Box::new(NativeBackend::from_plan(&plan))
    ///             as Box<dyn Backend>)
    ///     },
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// assert_eq!(router.replicas(), 2);
    /// router.shutdown();
    /// ```
    pub fn start<F>(factory: F, cfg: RouterConfig) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn Backend>>
            + Send
            + Sync
            + 'static,
    {
        assert!(cfg.replicas >= 1, "need at least one replica");
        let replicas = cfg.replicas;
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::with_replicas(replicas));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) =
            mpsc::channel::<anyhow::Result<(String, usize)>>();

        // Per-replica dispatch channels are bounded to ONE queued batch:
        // enough to keep a replica busy back to back, small enough that
        // saturation propagates to the admission queue (backpressure).
        let mut workers = Vec::with_capacity(replicas);
        let mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>> =
            Vec::with_capacity(replicas);
        for r in 0..replicas {
            let (btx, brx) = mpsc::sync_channel::<Batch>(1);
            batch_txs.push(Some(btx));
            let f = Arc::clone(&factory);
            let m = Arc::clone(&metrics);
            let rtx = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bk-replica{r}"))
                    .spawn(move || replica_loop(r, &*f, brx, &m, rtx))
                    .expect("spawn replica worker"),
            );
        }
        drop(ready_tx);

        // Collect startup results; the smallest backend capacity bounds
        // batch formation so every batch fits every replica.
        let mut backend_name = String::new();
        let mut min_cap = usize::MAX;
        for _ in 0..replicas {
            let result = match ready_rx.recv() {
                Ok(r) => r,
                // A worker died without reporting (panicked in factory).
                Err(_) => Err(anyhow::anyhow!(
                    "replica worker died during startup"
                )),
            };
            match result {
                Ok((name, cap)) => {
                    backend_name = name;
                    min_cap = min_cap.min(cap);
                }
                Err(e) => {
                    // Tear the pool down: dropping the dispatch channels
                    // ends every replica that did start.
                    drop(batch_txs);
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }

        let bcfg = BatcherConfig {
            // Never form batches larger than the smallest backend.
            max_batch: cfg.batcher.max_batch.min(min_cap),
            max_delay: cfg.batcher.max_delay,
        };
        let m = Arc::clone(&metrics);
        let batcher = std::thread::Builder::new()
            .name("bk-batcher".to_string())
            .spawn(move || batcher_loop(rx, bcfg, batch_txs, &m))
            .expect("spawn batcher");

        Ok(Self {
            tx: Some(tx),
            metrics,
            batcher: Some(batcher),
            workers,
            backend_name,
            replicas,
        })
    }

    /// Label of the backend the pool runs (all replicas share one
    /// factory, hence one label).
    pub fn backend_name(&self) -> &str {
        &self.backend_name
    }

    /// Number of worker replicas in the pool.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Shared handle to the router's counters.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Non-blocking submit; returns the reply channel.
    ///
    /// ```
    /// use bitkernel::coordinator::{Backend, MockBackend, Router,
    ///                              RouterConfig};
    ///
    /// let router = Router::start(
    ///     |_replica| Ok(Box::new(MockBackend::new(4, 0))
    ///                   as Box<dyn Backend>),
    ///     RouterConfig { replicas: 2, ..RouterConfig::default() },
    /// ).unwrap();
    /// let rx = router.submit(vec![0.5; 3 * 32 * 32]).unwrap();
    /// let reply = rx.recv().unwrap();
    /// assert_eq!(reply.logits.len(), 10);
    /// router.shutdown();
    /// ```
    pub fn submit(
        &self,
        image_chw: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferReply>, SubmitError> {
        assert_eq!(image_chw.len(), IMAGE_ELEMS, "image element count");
        let tx = self.tx.as_ref().ok_or(SubmitError::Shutdown)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            image: image_chw,
            submitted: Instant::now(),
            reply_tx,
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Submit and block for the reply.
    pub fn submit_wait(&self, image_chw: Vec<f32>) -> Result<InferReply, SubmitError> {
        let rx = self.submit(image_chw)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Graceful drain: stop admissions, let the batcher flush every
    /// queued request through the replicas, then join all threads.  No
    /// accepted request is dropped.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        drop(self.tx.take());
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.drain();
    }
}

/// One replica worker: construct the backend, report readiness, then
/// execute dispatched batches until the batcher hangs up.
fn replica_loop(
    replica: usize,
    factory: &BackendFactory,
    brx: mpsc::Receiver<Batch>,
    m: &Metrics,
    ready_tx: mpsc::Sender<anyhow::Result<(String, usize)>>,
) {
    let mut backend = match factory(replica) {
        Ok(b) => {
            let _ = ready_tx.send(Ok((b.name().to_string(), b.max_batch())));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);
    let cap = backend.max_batch();
    let rm = &m.replicas[replica];
    while let Ok(batch) = brx.recv() {
        let Batch { formed, reqs } = batch;
        let b = reqs.len();
        // Assemble the (padded) image tensor.
        let mut data = vec![0.0f32; cap * IMAGE_ELEMS];
        for (i, r) in reqs.iter().enumerate() {
            data[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS]
                .copy_from_slice(&r.image);
        }
        let images = Tensor::new(vec![cap, 3, 32, 32], data);
        let infer_sw = Instant::now();
        let result = backend.infer(&images);
        let infer_us = infer_sw.elapsed().as_micros() as u64;
        rm.batches.fetch_add(1, Ordering::Relaxed);
        rm.requests.fetch_add(b as u64, Ordering::Relaxed);
        rm.busy_us.fetch_add(infer_us, Ordering::Relaxed);
        rm.infer_latency.record_us(infer_us);
        match result {
            Ok(logits) => {
                let done = Instant::now();
                for (i, r) in reqs.into_iter().enumerate() {
                    let row = logits.row(i).to_vec();
                    let reply = InferReply {
                        class: argmax(&row),
                        logits: row,
                        queue_us: (formed - r.submitted).as_micros() as u64,
                        total_us: (done - r.submitted).as_micros() as u64,
                    };
                    m.total_latency.record_us(reply.total_us);
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply_tx.send(reply);
                }
            }
            Err(e) => {
                crate::log_error!(
                    "replica {replica} inference failed: {e:#}"
                );
                // Drop the requests; their reply channels disconnect,
                // which callers observe as an error.
                m.rejected.fetch_add(b as u64, Ordering::Relaxed);
            }
        }
        rm.inflight.fetch_sub(b as u64, Ordering::Relaxed);
    }
}

/// The batcher thread: form batches, dispatch each to the least-loaded
/// replica.  Exits (dropping the dispatch channels, which drains the
/// workers) when every submitter hung up and the queue is empty.
fn batcher_loop(
    rx: mpsc::Receiver<Request>,
    bcfg: BatcherConfig,
    mut batch_txs: Vec<Option<mpsc::SyncSender<Batch>>>,
    m: &Metrics,
) {
    let batcher = DynamicBatcher::new(rx, bcfg);
    while let Some(reqs) = batcher.next_batch() {
        let formed = Instant::now();
        let b = reqs.len();
        m.batches.fetch_add(1, Ordering::Relaxed);
        m.batched_requests.fetch_add(b as u64, Ordering::Relaxed);
        for r in &reqs {
            m.queue_latency
                .record_us((formed - r.submitted).as_micros() as u64);
        }
        dispatch(Batch { formed, reqs }, &mut batch_txs, m);
    }
}

/// Least-loaded dispatch: try replicas in ascending in-flight order
/// without blocking; if every dispatch slot is full, block on the
/// least-loaded live replica (which stalls the batcher and, in turn,
/// fills the admission queue — the backpressure path).  Replicas whose
/// worker died are retired from the rotation.
fn dispatch(
    mut batch: Batch,
    batch_txs: &mut [Option<mpsc::SyncSender<Batch>>],
    m: &Metrics,
) {
    let b = batch.reqs.len() as u64;
    loop {
        let mut order: Vec<usize> = (0..batch_txs.len())
            .filter(|&r| batch_txs[r].is_some())
            .collect();
        if order.is_empty() {
            // Every replica died: shed the batch (reply channels drop).
            m.rejected.fetch_add(b, Ordering::Relaxed);
            return;
        }
        order.sort_by_key(|&r| {
            m.replicas[r].inflight.load(Ordering::Relaxed)
        });
        // Pass 1: non-blocking, in load order.
        for &r in &order {
            let rm = &m.replicas[r];
            rm.inflight.fetch_add(b, Ordering::Relaxed);
            match batch_txs[r].as_ref().unwrap().try_send(batch) {
                Ok(()) => return,
                Err(mpsc::TrySendError::Full(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch = back;
                }
                Err(mpsc::TrySendError::Disconnected(back)) => {
                    rm.inflight.fetch_sub(b, Ordering::Relaxed);
                    batch_txs[r] = None;
                    batch = back;
                }
            }
        }
        // Pass 2: every slot full — block on the least-loaded replica.
        let r = order[0];
        if batch_txs[r].is_none() {
            continue; // retired during pass 1; recompute the order
        }
        let rm = &m.replicas[r];
        rm.inflight.fetch_add(b, Ordering::Relaxed);
        match batch_txs[r].as_ref().unwrap().send(batch) {
            Ok(()) => return,
            Err(mpsc::SendError(back)) => {
                rm.inflight.fetch_sub(b, Ordering::Relaxed);
                batch_txs[r] = None;
                batch = back;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn image(v: f32) -> Vec<f32> {
        vec![v; IMAGE_ELEMS]
    }

    #[test]
    fn submit_roundtrip() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(4, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let reply = router.submit_wait(image(0.9)).unwrap();
        assert_eq!(reply.logits.len(), 10);
        assert!(reply.class >= 8, "{}", reply.class); // high mean -> high class
        assert!(reply.total_us >= reply.queue_us);
        let snap = router.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.replicas.len(), router.replicas());
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            1
        );
    }

    #[test]
    fn batches_multiple_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_calls(
                    8,
                    5,
                    Arc::clone(&calls2),
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 64,
                replicas: 1, // a single replica pins the batch count
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(50),
                },
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // All 8 should have ridden one or two batches, not 8 singles.
        let n = calls.load(Ordering::SeqCst);
        assert!(n <= 2, "backend called {n} times");
        assert!(router.metrics().snapshot().mean_batch_size >= 4.0);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow backend + tiny queue -> QueueFull.
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 50)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 2,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let mut rejected = 0;
        let mut kept = Vec::new();
        for _ in 0..20 {
            match router.submit(image(0.0)) {
                Ok(rx) => kept.push(rx),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "expected rejections");
        for rx in kept {
            let _ = rx.recv();
        }
        assert_eq!(router.metrics().snapshot().rejected, rejected);
    }

    #[test]
    fn least_loaded_dispatch_spreads_across_replicas() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(1, 10)) as Box<dyn Backend>),
            RouterConfig {
                queue_cap: 64,
                replicas: 4,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|_| router.submit(image(0.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snap = router.metrics().snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            16
        );
        let used = snap.replicas.iter().filter(|r| r.requests > 0).count();
        assert!(used >= 2, "dispatch never spread: {:?}", snap.replicas);
        // Everything settled: no in-flight work left behind.
        assert!(snap.replicas.iter().all(|r| r.inflight == 0));
        assert!(snap.replicas.iter().all(|r| r.busy_us > 0
                || r.requests == 0));
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let r = router.submit_wait(image(0.1)).unwrap();
        assert_eq!(r.logits.len(), 10);
        router.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let router = Router::start(
            |_| Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap();
        let metrics = router.metrics();
        router.shutdown();
        let _ = metrics.snapshot(); // metrics survive shutdown
    }

    #[test]
    fn factory_failure_on_any_replica_is_synchronous() {
        let r = Router::start(
            |replica| {
                if replica == 1 {
                    anyhow::bail!("replica 1 refused")
                }
                Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        );
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("refused"));
    }
}
