//! Serving metrics: counters + a lock-free log-bucketed latency
//! histogram (offline substrate for an HDR-histogram crate).
//!
//! Since the replica-pool redesign the router tracks two levels:
//!
//! * **router-wide** — admission (`submitted`/`rejected`), batch
//!   formation (`batches`, `batched_requests`, `queue_latency`) and
//!   end-to-end completion (`completed`, `total_latency`);
//! * **per-replica** — one [`ReplicaMetrics`] entry per worker in the
//!   pool: batches/requests executed, time spent inside
//!   `Backend::infer` (`busy_us`, the utilization numerator), a
//!   per-batch inference-latency histogram, and the live `inflight`
//!   gauge the batcher uses for least-loaded dispatch.
//!
//! Everything is atomic and write-cheap: the request path only does
//! relaxed `fetch_add`s.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram with logarithmic buckets from 1us to ~17min.
/// Bucket i covers [2^i, 2^(i+1)) microseconds.
const BUCKETS: usize = 30;

/// Lock-free log-bucketed latency histogram (microsecond samples).
#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    /// Record one sample, in microseconds.
    pub fn record_us(&self, us: u64) {
        let idx = (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean sample value in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// Counters for one replica worker in the pool.
pub struct ReplicaMetrics {
    /// Batches executed by this replica.
    pub batches: AtomicU64,
    /// Requests carried by those batches.
    pub requests: AtomicU64,
    /// Requests currently queued on or running inside this replica —
    /// the least-loaded dispatch key, incremented by the batcher at
    /// dispatch and decremented by the worker after the batch finishes.
    pub inflight: AtomicU64,
    /// Cumulative wall time spent inside `Backend::infer`, in µs.
    /// Utilization over a window = Δbusy_us / Δwall_us.
    pub busy_us: AtomicU64,
    /// Per-batch `Backend::infer` wall time.
    pub infer_latency: Histogram,
    /// Times this replica's backend was rebuilt after a panic
    /// (supervision — `bitkernel_replica_restarts`).
    pub restarts: AtomicU64,
    /// Gauge (0/1): the replica is currently down, mid-respawn.  The
    /// dispatcher deprioritizes restarting replicas; every replica
    /// restarting at once opens the router's circuit.
    pub restarting: AtomicU64,
    /// NUMA node this replica's worker pinned itself to
    /// ([`super::NumaPolicy::RoundRobin`]); [`u64::MAX`] = unpinned
    /// (policy off, no topology, or the pin failed).
    pub numa_node: AtomicU64,
}

impl Default for ReplicaMetrics {
    fn default() -> Self {
        Self {
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            infer_latency: Histogram::default(),
            restarts: AtomicU64::new(0),
            restarting: AtomicU64::new(0),
            // Sentinel, not zero: node 0 is a real node.
            numa_node: AtomicU64::new(u64::MAX),
        }
    }
}

/// All coordinator counters.  `default()` builds a router-wide-only
/// instance (no replica entries); the router uses
/// [`Metrics::with_replicas`].
#[derive(Default)]
pub struct Metrics {
    /// Requests accepted into the admission queue.
    pub submitted: AtomicU64,
    /// Requests completed with a reply.
    pub completed: AtomicU64,
    /// Requests shed: admission-queue rejections plus requests dropped
    /// by a failing backend.
    pub rejected: AtomicU64,
    /// Batches formed by the batcher.
    pub batches: AtomicU64,
    /// Requests carried by formed batches.
    pub batched_requests: AtomicU64,
    /// Requests answered `DeadlineExceeded` by a replica WITHOUT
    /// running inference (their deadline passed while queued).
    pub deadline_expired: AtomicU64,
    /// Replica panics caught by the supervision wrapper (each one
    /// triggers a respawn).
    pub panics: AtomicU64,
    /// Panicked batches whose single member was individually
    /// identified as the poison (`ReplyError::ReplicaPanicked {
    /// quarantined: true }`).
    pub quarantined: AtomicU64,
    /// Submit -> batch-formation latency.
    pub queue_latency: Histogram,
    /// Submit -> reply latency.
    pub total_latency: Histogram,
    /// Per-replica counters, indexed by replica id.
    pub replicas: Vec<ReplicaMetrics>,
}

/// Point-in-time copy of one [`ReplicaMetrics`].
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    /// Batches executed by this replica.
    pub batches: u64,
    /// Requests carried by those batches.
    pub requests: u64,
    /// Requests queued on or running inside this replica right now.
    pub inflight: u64,
    /// Cumulative µs spent inside `Backend::infer`.
    pub busy_us: u64,
    /// Median per-batch inference latency, µs.
    pub infer_p50_us: u64,
    /// p99 per-batch inference latency, µs.
    pub infer_p99_us: u64,
    /// Times this replica's backend was rebuilt after a panic.
    pub restarts: u64,
    /// Whether the replica is currently down, mid-respawn.
    pub restarting: bool,
    /// NUMA node the worker is pinned to (`None` = unpinned).
    pub numa_node: Option<u64>,
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Requests accepted into the admission queue.
    pub submitted: u64,
    /// Requests completed with a reply.
    pub completed: u64,
    /// Requests shed (queue-full rejections + backend failures).
    pub rejected: u64,
    /// Requests answered `DeadlineExceeded` without inference.
    pub deadline_expired: u64,
    /// Replica panics caught by the supervision wrapper.
    pub panics: u64,
    /// Quarantined single-request panicked batches.
    pub quarantined: u64,
    /// Batches formed.
    pub batches: u64,
    /// Mean requests per formed batch.
    pub mean_batch_size: f64,
    /// Mean submit -> batch-formation latency, µs.
    pub queue_mean_us: f64,
    /// p99 submit -> batch-formation latency, µs.
    pub queue_p99_us: u64,
    /// Mean submit -> reply latency, µs.
    pub latency_mean_us: f64,
    /// Median submit -> reply latency, µs.
    pub latency_p50_us: u64,
    /// p99 submit -> reply latency, µs.
    pub latency_p99_us: u64,
    /// Per-replica snapshots, indexed by replica id.
    pub replicas: Vec<ReplicaSnapshot>,
}

impl Metrics {
    /// Metrics for a router driving `replicas` workers.
    pub fn with_replicas(replicas: usize) -> Self {
        Self {
            replicas: (0..replicas).map(|_| ReplicaMetrics::default()).collect(),
            ..Self::default()
        }
    }

    /// Copy every counter into a [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64
                    / batches as f64
            },
            queue_mean_us: self.queue_latency.mean_us(),
            queue_p99_us: self.queue_latency.quantile_us(0.99),
            latency_mean_us: self.total_latency.mean_us(),
            latency_p50_us: self.total_latency.quantile_us(0.5),
            latency_p99_us: self.total_latency.quantile_us(0.99),
            replicas: self
                .replicas
                .iter()
                .map(|r| ReplicaSnapshot {
                    batches: r.batches.load(Ordering::Relaxed),
                    requests: r.requests.load(Ordering::Relaxed),
                    inflight: r.inflight.load(Ordering::Relaxed),
                    busy_us: r.busy_us.load(Ordering::Relaxed),
                    infer_p50_us: r.infer_latency.quantile_us(0.5),
                    infer_p99_us: r.infer_latency.quantile_us(0.99),
                    restarts: r.restarts.load(Ordering::Relaxed),
                    restarting: r.restarting.load(Ordering::Relaxed) != 0,
                    numa_node: match r.numa_node.load(Ordering::Relaxed) {
                        u64::MAX => None,
                        n => Some(n),
                    },
                })
                .collect(),
        }
    }

    /// Prometheus-style exposition for GET /metrics.
    pub fn render_prometheus(&self) -> String {
        self.render_prometheus_labeled("")
    }

    /// Render one exposition line: `name{labels} value` (`labels` may
    /// be empty).  For registry-level series (mounted-model gauge,
    /// per-model mount epoch) that live outside any one router's
    /// [`Metrics`].
    pub fn render_series(name: &str, labels: &str, value: u64) -> String {
        if labels.is_empty() {
            format!("{name} {value}\n")
        } else {
            format!("{name}{{{labels}}} {value}\n")
        }
    }

    /// Prometheus-style exposition with `extra` (e.g. `model="bnn"`,
    /// may be empty) merged into every line's label set.  Per-replica
    /// lines additionally carry a `replica="<id>"` label — merging
    /// happens here, NOT by textual postprocessing in the HTTP layer,
    /// so labelled and label-free lines stay well-formed.
    pub fn render_prometheus_labeled(&self, extra: &str) -> String {
        let s = self.snapshot();
        let labels = |more: &str| -> String {
            match (extra.is_empty(), more.is_empty()) {
                (true, true) => String::new(),
                (true, false) => format!("{{{more}}}"),
                (false, true) => format!("{{{extra}}}"),
                (false, false) => format!("{{{extra},{more}}}"),
            }
        };
        let l = labels("");
        let mut out = format!(
            "bitkernel_requests_submitted{l} {}\n\
             bitkernel_requests_completed{l} {}\n\
             bitkernel_requests_rejected{l} {}\n\
             bitkernel_requests_deadline_expired{l} {}\n\
             bitkernel_replica_panics{l} {}\n\
             bitkernel_requests_quarantined{l} {}\n\
             bitkernel_batches_total{l} {}\n\
             bitkernel_batch_size_mean{l} {:.3}\n\
             bitkernel_queue_latency_mean_us{l} {:.1}\n\
             bitkernel_queue_latency_p99_us{l} {}\n\
             bitkernel_latency_mean_us{l} {:.1}\n\
             bitkernel_latency_p50_us{l} {}\n\
             bitkernel_latency_p99_us{l} {}\n",
            s.submitted,
            s.completed,
            s.rejected,
            s.deadline_expired,
            s.panics,
            s.quarantined,
            s.batches,
            s.mean_batch_size,
            s.queue_mean_us,
            s.queue_p99_us,
            s.latency_mean_us,
            s.latency_p50_us,
            s.latency_p99_us,
        );
        for (i, r) in s.replicas.iter().enumerate() {
            let rl = labels(&format!("replica=\"{i}\""));
            out.push_str(&format!(
                "bitkernel_replica_batches{rl} {}\n\
                 bitkernel_replica_requests{rl} {}\n\
                 bitkernel_replica_inflight{rl} {}\n\
                 bitkernel_replica_busy_us{rl} {}\n\
                 bitkernel_replica_infer_p50_us{rl} {}\n\
                 bitkernel_replica_infer_p99_us{rl} {}\n\
                 bitkernel_replica_restarts{rl} {}\n\
                 bitkernel_replica_restarting{rl} {}\n",
                r.batches,
                r.requests,
                r.inflight,
                r.busy_us,
                r.infer_p50_us,
                r.infer_p99_us,
                r.restarts,
                u64::from(r.restarting),
            ));
            // Only pinned replicas emit the placement gauge — an
            // absent series is "unpinned", not "node 0".
            if let Some(node) = r.numa_node {
                out.push_str(&format!(
                    "bitkernel_replica_numa_node{rl} {node}\n"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 1000, "{p50}");
        assert!((h.mean_us() - 22222.0).abs() < 1000.0);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_batch_mean() {
        let m = Metrics::default();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size, 2.5);
        assert!(m.render_prometheus().contains("bitkernel_batches_total 4"));
    }

    #[test]
    fn replica_counters_surface_in_snapshot_and_prometheus() {
        let m = Metrics::with_replicas(2);
        m.replicas[1].batches.store(3, Ordering::Relaxed);
        m.replicas[1].requests.store(24, Ordering::Relaxed);
        m.replicas[1].busy_us.store(500, Ordering::Relaxed);
        m.replicas[1].infer_latency.record_us(100);
        let s = m.snapshot();
        assert_eq!(s.replicas.len(), 2);
        assert_eq!(s.replicas[1].batches, 3);
        assert_eq!(s.replicas[1].requests, 24);
        assert_eq!(s.replicas[0].batches, 0);
        let text = m.render_prometheus();
        assert!(text.contains("bitkernel_replica_batches{replica=\"1\"} 3"),
                "{text}");
        // Merged labels stay well-formed (single brace pair).
        let labelled = m.render_prometheus_labeled("model=\"bnn\"");
        assert!(labelled.contains(
            "bitkernel_replica_requests{model=\"bnn\",replica=\"1\"} 24"
        ), "{labelled}");
        assert!(labelled.contains("bitkernel_batches_total{model=\"bnn\"} 0"),
                "{labelled}");
        assert!(!labelled.contains("}{"), "{labelled}");
    }

    #[test]
    fn numa_gauge_absent_until_pinned() {
        let m = Metrics::with_replicas(2);
        assert!(m.snapshot().replicas.iter()
                    .all(|r| r.numa_node.is_none()));
        assert!(!m.render_prometheus()
                     .contains("bitkernel_replica_numa_node"));
        // Replica 1 pins to node 0: the gauge appears for it only,
        // and node id 0 is distinguishable from "unpinned".
        m.replicas[1].numa_node.store(0, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.replicas[0].numa_node, None);
        assert_eq!(s.replicas[1].numa_node, Some(0));
        let text = m.render_prometheus();
        assert!(text.contains(
            "bitkernel_replica_numa_node{replica=\"1\"} 0"
        ), "{text}");
        assert!(!text.contains(
            "bitkernel_replica_numa_node{replica=\"0\"}"
        ), "{text}");
    }

    #[test]
    fn supervision_counters_surface_everywhere() {
        let m = Metrics::with_replicas(2);
        m.panics.store(3, Ordering::Relaxed);
        m.quarantined.store(1, Ordering::Relaxed);
        m.deadline_expired.store(7, Ordering::Relaxed);
        m.replicas[0].restarts.store(3, Ordering::Relaxed);
        m.replicas[1].restarting.store(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.panics, 3);
        assert_eq!(s.quarantined, 1);
        assert_eq!(s.deadline_expired, 7);
        assert_eq!(s.replicas[0].restarts, 3);
        assert!(!s.replicas[0].restarting);
        assert!(s.replicas[1].restarting);
        let text = m.render_prometheus_labeled("model=\"bnn\"");
        assert!(text.contains(
            "bitkernel_replica_restarts{model=\"bnn\",replica=\"0\"} 3"
        ), "{text}");
        assert!(text.contains(
            "bitkernel_replica_restarting{model=\"bnn\",replica=\"1\"} 1"
        ), "{text}");
        assert!(text.contains(
            "bitkernel_requests_deadline_expired{model=\"bnn\"} 7"
        ), "{text}");
        assert!(text.contains("bitkernel_replica_panics{model=\"bnn\"} 3"),
                "{text}");
    }
}
