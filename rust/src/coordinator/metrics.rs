//! Serving metrics: counters + a lock-free log-bucketed latency
//! histogram (offline substrate for an HDR-histogram crate).

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram with logarithmic buckets from 1us to ~17min.
/// Bucket i covers [2^i, 2^(i+1)) microseconds.
const BUCKETS: usize = 30;

#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// All coordinator counters.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub queue_latency: Histogram,
    pub total_latency: Histogram,
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub queue_mean_us: f64,
    pub queue_p99_us: u64,
    pub latency_mean_us: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64
                    / batches as f64
            },
            queue_mean_us: self.queue_latency.mean_us(),
            queue_p99_us: self.queue_latency.quantile_us(0.99),
            latency_mean_us: self.total_latency.mean_us(),
            latency_p50_us: self.total_latency.quantile_us(0.5),
            latency_p99_us: self.total_latency.quantile_us(0.99),
        }
    }

    /// Prometheus-style exposition for GET /metrics.
    pub fn render_prometheus(&self) -> String {
        let s = self.snapshot();
        format!(
            "bitkernel_requests_submitted {}\n\
             bitkernel_requests_completed {}\n\
             bitkernel_requests_rejected {}\n\
             bitkernel_batches_total {}\n\
             bitkernel_batch_size_mean {:.3}\n\
             bitkernel_queue_latency_mean_us {:.1}\n\
             bitkernel_queue_latency_p99_us {}\n\
             bitkernel_latency_mean_us {:.1}\n\
             bitkernel_latency_p50_us {}\n\
             bitkernel_latency_p99_us {}\n",
            s.submitted,
            s.completed,
            s.rejected,
            s.batches,
            s.mean_batch_size,
            s.queue_mean_us,
            s.queue_p99_us,
            s.latency_mean_us,
            s.latency_p50_us,
            s.latency_p99_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 1000, "{p50}");
        assert!((h.mean_us() - 22222.0).abs() < 1000.0);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_batch_mean() {
        let m = Metrics::default();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size, 2.5);
        assert!(m.render_prometheus().contains("bitkernel_batches_total 4"));
    }
}
