//! The serving coordinator: dynamic batching over a pool of replicated
//! inference backends, with bounded-queue backpressure and latency
//! metrics.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!     client -> Router::submit -> bounded queue -> batcher thread
//!            -(least-loaded)-> replica worker 0..N
//!               (native Session or PJRT executable)  -> response
//! ```
//!
//! The batcher forms batches **continuously**: with idle replicas it
//! follows the classic max-size/max-delay policy (a batch closes when
//! `max_batch` requests are waiting or the oldest has waited
//! `max_delay`); with every replica busy it keeps the batch open,
//! admitting queued requests until the instant a replica frees, then
//! dispatches at once.  Each batch goes to the replica with the fewest
//! in-flight requests; on the
//! native arm every replica is a [`model::Session`](crate::model::Session)
//! minted from ONE shared compiled [`Plan`](crate::model::Plan), so the
//! pool pays one compile and N buffer sets.  `benches/batching.rs`
//! sweeps replicas × max_batch × max_delay and emits `BENCH_3.json`.
//!
//! See `docs/ARCHITECTURE.md` for the full design and
//! `docs/SERVING.md` for the operator's view of the knobs.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod numa;
pub mod router;

pub use backend::{Backend, MockBackend, NativeBackend, PjrtBackend};
pub use batcher::{
    BatchBuffer, BatcherConfig, ContinuousBatcher, DynamicBatcher,
};
pub use metrics::{Metrics, MetricsSnapshot, ReplicaMetrics, ReplicaSnapshot};
pub use numa::{NumaNode, NumaPolicy};
pub use router::{default_replicas, BackendFactory, InferReply, ReplyError,
                 RequestError, Router, RouterConfig, SubmitError,
                 SubmitOptions};
