//! The serving coordinator: dynamic batching over pluggable inference
//! backends, with bounded-queue backpressure and latency metrics.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!     client -> Router::submit -> bounded queue -> batcher thread
//!            -> worker (native engine or PJRT executable) -> response
//! ```
//!
//! The batcher implements the classic max-size/max-delay policy: a batch
//! closes when `max_batch` requests are waiting or the oldest request
//! has waited `max_delay`, whichever comes first — the knob the
//! `benches/batching.rs` harness sweeps.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod router;

pub use backend::{Backend, MockBackend, NativeBackend, PjrtBackend};
pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{InferReply, Router, RouterConfig, SubmitError};
