//! NUMA topology discovery + replica core pinning.
//!
//! On multi-socket hosts a replica whose worker thread migrates across
//! nodes pays remote-memory latency on every gemm: its `Session`
//! scratch and its [`BatchBuffer`](super::BatchBuffer) were first
//! touched — hence physically placed — wherever the thread happened to
//! run at construction time.  The fix is placement, not allocation:
//! pin each replica worker to ONE node's cores *before* it builds its
//! backend and batch buffer, so first-touch puts every hot page on the
//! node the thread will run on for its whole life (respawns rebuild on
//! the same pinned thread, so placement survives supervision).
//!
//! Topology comes from sysfs (`/sys/devices/system/node/node*/cpulist`
//! — kernel ABI, stable text like `0-7,16-23`), and pinning is a
//! direct `sched_setaffinity` syscall declared inline: the container
//! carries no `libc` crate, so this module uses the same raw
//! `extern "C"` idiom as [`crate::model::Mmap`].  Non-linux builds
//! see an empty topology and no-op pinning — callers never branch on
//! platform.
//!
//! Policy ([`NumaPolicy`], wired through
//! [`RouterConfig::numa_policy`](super::RouterConfig::numa_policy) and
//! `serve --numa`): `Off` keeps today's behavior; `RoundRobin` deals
//! nodes to replicas in order (`replica r -> node r % N`), which
//! spreads the pool evenly across sockets and keeps each replica's
//! working set local.  Each replica's assignment is exported as
//! `bitkernel_replica_numa_node` on `/metrics`.

use std::io;
use std::path::Path;

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    /// u64 words in the affinity mask: 16 * 64 = 1024 cpus, the
    /// kernel's default CPU_SETSIZE.
    pub const MASK_WORDS: usize = 16;

    extern "C" {
        /// pid 0 = the calling thread (glibc routes this to the
        /// per-thread syscall, which is exactly what pinning wants).
        pub fn sched_setaffinity(
            pid: c_int,
            cpusetsize: usize,
            mask: *const u64,
        ) -> c_int;
        pub fn sched_getaffinity(
            pid: c_int,
            cpusetsize: usize,
            mask: *mut u64,
        ) -> c_int;
    }
}

/// One NUMA node: its sysfs id and the cpus it owns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NumaNode {
    /// Node id (the `N` in `/sys/devices/system/node/nodeN`).
    pub id: usize,
    /// The cpus on this node, ascending.
    pub cpus: Vec<usize>,
}

/// How the router places replica workers on NUMA nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NumaPolicy {
    /// No pinning — threads float wherever the scheduler puts them
    /// (the pre-NUMA behavior, and the default).
    #[default]
    Off,
    /// Deal nodes to replicas round-robin (`replica r -> node r % N`)
    /// and pin each worker to its node's cores before it builds its
    /// backend, so first-touch places its buffers locally.
    RoundRobin,
}

/// Parse a sysfs cpulist (`"0-7,16-23"`, trailing newline ok) into the
/// cpu ids it names, ascending.  Malformed segments are skipped — the
/// kernel won't produce them, and a partial answer beats a panic in a
/// serving process reading an exotic sysfs.
pub fn parse_cpulist(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for seg in list.trim().split(',') {
        let seg = seg.trim();
        if seg.is_empty() {
            continue;
        }
        match seg.split_once('-') {
            Some((lo, hi)) => {
                let (Ok(lo), Ok(hi)) =
                    (lo.trim().parse::<usize>(), hi.trim().parse::<usize>())
                else {
                    continue;
                };
                if lo <= hi && hi - lo < 4096 {
                    cpus.extend(lo..=hi);
                }
            }
            None => {
                if let Ok(c) = seg.parse::<usize>() {
                    cpus.push(c);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// Read one node's cpulist file.
fn node_cpus(dir: &Path) -> Option<Vec<usize>> {
    let text = std::fs::read_to_string(dir.join("cpulist")).ok()?;
    let cpus = parse_cpulist(&text);
    (!cpus.is_empty()).then_some(cpus)
}

/// Discover the host's NUMA topology from
/// `/sys/devices/system/node/node*/cpulist`, ascending by node id.
/// Empty on non-linux hosts, containers that hide sysfs, and anything
/// else unreadable — "no topology" rather than an error, so callers
/// degrade to unpinned.
pub fn nodes() -> Vec<NumaNode> {
    nodes_from("/sys/devices/system/node")
}

/// [`nodes`] over an arbitrary sysfs root (tests point this at a
/// fixture directory).
pub fn nodes_from(root: impl AsRef<Path>) -> Vec<NumaNode> {
    let Ok(entries) = std::fs::read_dir(root.as_ref()) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(idx) = name.strip_prefix("node") else { continue };
        let Ok(id) = idx.parse::<usize>() else { continue };
        if let Some(cpus) = node_cpus(&entry.path()) {
            out.push(NumaNode { id, cpus });
        }
    }
    out.sort_by_key(|n| n.id);
    out
}

/// Pin the calling thread to exactly `cpus`.  An empty set is
/// `InvalidInput`; cpus past the 1024-bit kernel mask are
/// `InvalidInput` too (no silent truncation).  On non-linux targets
/// this is a no-op `Ok` — there is nothing to pin to, and [`nodes`] is
/// empty there anyway.
pub fn pin_current_thread(cpus: &[usize]) -> io::Result<()> {
    if cpus.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "empty cpu set",
        ));
    }
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; sys::MASK_WORDS];
        for &c in cpus {
            if c >= sys::MASK_WORDS * 64 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("cpu {c} exceeds the affinity mask"),
                ));
            }
            mask[c / 64] |= 1u64 << (c % 64);
        }
        // SAFETY: mask is a live [u64; 16] and the size matches; pid 0
        // targets only the calling thread.
        let rc = unsafe {
            sys::sched_setaffinity(
                0,
                std::mem::size_of_val(&mask),
                mask.as_ptr(),
            )
        };
        if rc != 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

/// The cpus the calling thread may currently run on (empty on
/// non-linux targets or when the syscall fails).  Diagnostic
/// counterpart to [`pin_current_thread`].
pub fn current_affinity() -> Vec<usize> {
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; sys::MASK_WORDS];
        // SAFETY: mask is a live, writable [u64; 16] of matching size.
        let rc = unsafe {
            sys::sched_getaffinity(
                0,
                std::mem::size_of_val(&mask),
                mask.as_mut_ptr(),
            )
        };
        if rc == 0 {
            return mask
                .iter()
                .enumerate()
                .flat_map(|(w, &bits)| {
                    (0..64).filter_map(move |b| {
                        ((bits >> b) & 1 == 1).then_some(w * 64 + b)
                    })
                })
                .collect();
        }
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_singles_and_junk() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4,6-7\n"), vec![0, 1, 4, 6, 7]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(" 2 , 0 - 1 "), vec![0, 1, 2]);
        assert_eq!(parse_cpulist("3,1-2,2-3"), vec![1, 2, 3]); // dedup
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("x,7-4,1"), vec![1]); // junk skipped
    }

    #[test]
    fn fixture_topology_round_trips() {
        let root = std::env::temp_dir()
            .join(format!("bk-numa-fixture-{}", std::process::id()));
        for (node, list) in
            [("node1", "8-15\n"), ("node0", "0-3,4-7\n")]
        {
            let d = root.join(node);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        // Non-node entries are ignored.
        std::fs::create_dir_all(root.join("possible")).unwrap();
        let nodes = nodes_from(&root);
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].id, 0);
        assert_eq!(nodes[0].cpus, (0..8).collect::<Vec<_>>());
        assert_eq!(nodes[1].id, 1);
        assert_eq!(nodes[1].cpus, (8..16).collect::<Vec<_>>());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_sysfs_means_no_topology() {
        assert!(nodes_from("/definitely/not/sysfs").is_empty());
    }

    #[test]
    fn empty_pin_is_rejected() {
        assert!(pin_current_thread(&[]).is_err());
        // Out-of-mask cpus are rejected where a mask exists at all.
        #[cfg(target_os = "linux")]
        assert!(pin_current_thread(&[usize::MAX]).is_err());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pin_round_trips_through_getaffinity() {
        // Pin a scratch thread (not the test runner's) to the first
        // cpu this process may use, and read the mask back.
        let allowed = current_affinity();
        assert!(!allowed.is_empty(), "getaffinity failed");
        let target = allowed[0];
        std::thread::spawn(move || {
            pin_current_thread(&[target]).unwrap();
            assert_eq!(current_affinity(), vec![target]);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn real_topology_is_sane_when_present() {
        // Containers may hide /sys — only assert when it's there.
        for n in nodes() {
            assert!(!n.cpus.is_empty());
        }
    }
}
