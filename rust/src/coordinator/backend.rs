//! Inference backends the coordinator can drive.
//!
//! A [`Backend`] consumes a fixed-capacity image batch and returns
//! logits.  Three implementations:
//! * [`NativeBackend`] — the in-process rust engine (Table-2 CPU arm),
//!   a compiled [`Session`] over the requested kernel arm,
//! * [`PjrtBackend`]   — an AOT-compiled XLA executable (accelerator arm),
//! * [`MockBackend`]   — deterministic stub for coordinator tests.
//!
//! The trait is shaped for the request path: `name` borrows (metrics
//! labels allocate nothing) and `infer` returns the logits by reference
//! into backend-owned storage, so the native backend's inference step
//! itself allocates nothing in steady state.  (The router's worker loop
//! reuses a per-replica padded batch tensor — see `router.rs` — so the
//! zero-alloc guarantee is scoped to `Session::run` inside `infer`.)
//!
//! Every backend also publishes its **shape contract** —
//! [`Backend::input_shape`], [`Backend::classes`], and optionally
//! [`Backend::labels`] — which the [`super::Router`] captures at
//! startup: submissions are validated against it, the padded batch
//! tensor is sized from it, and the HTTP layer derives per-model
//! request/reply schemas from it.  Nothing outside the model file
//! hardwires an image geometry.

use anyhow::Result;

use crate::bitops::XnorImpl;
use crate::model::{BnnEngine, EngineKernel, Plan, Session};
use crate::runtime::LoadedModel;
use crate::tensor::Tensor;

/// A batched inference backend.  `infer` receives exactly
/// `max_batch()` images ([B, C, H, W] normalized, matching
/// [`Backend::input_shape`]) — the worker pads short batches — and
/// returns logits [B, [`Backend::classes`]], valid until the next
/// `infer` call.
///
/// NOT `Send`: PJRT handles contain thread-affine state (`Rc`, raw
/// pointers), so the router constructs every backend INSIDE its
/// replica worker thread via a `Send + Sync` factory closure called
/// once per replica (see [`super::Router::start`]).
pub trait Backend {
    /// Stable label for logs and metrics (e.g. `native/xnor/auto`).
    fn name(&self) -> &str;
    /// Largest batch `infer` accepts (the worker pads up to it).
    fn max_batch(&self) -> usize;
    /// Per-image input shape (C, H, W) `infer` expects — the model's
    /// geometry, read off its plan/executable, never assumed.
    fn input_shape(&self) -> (usize, usize, usize);
    /// Number of output classes (`infer` returns [B, classes] logits).
    fn classes(&self) -> usize;
    /// Class-label table, when the model carries one (`labels()[c]`
    /// names class `c`).  Default: none — replies fall back to numeric
    /// labels.
    fn labels(&self) -> Option<&[String]> {
        None
    }
    /// Run one padded batch; the returned logits borrow backend-owned
    /// storage and stay valid until the next call.
    fn infer(&mut self, images: &Tensor) -> Result<&Tensor>;
}

/// Native rust engine backend (any [`EngineKernel`] arm): a compiled
/// plan's [`Session`], so every request batch reuses the same buffers.
/// The engine itself is NOT retained — the plan shares its weights.
pub struct NativeBackend {
    name: String,
    input_shape: (usize, usize, usize),
    classes: usize,
    labels: Option<Vec<String>>,
    session: Session,
}

impl NativeBackend {
    /// Compile a fresh plan for `(kernel, batch)` and back it with one
    /// session.  For a replica pool, prefer compiling once and calling
    /// [`NativeBackend::from_plan`] per replica.
    pub fn new(engine: &BnnEngine, kernel: EngineKernel, batch: usize)
               -> Self {
        Self::from_plan(
            &engine
                .plan(kernel, batch)
                .expect("batch >= 1 and spec validated at load"),
        )
    }

    /// Backend over an already-compiled, shared [`Plan`] — the
    /// replica-pool path: [`super::Router::start`] calls its factory
    /// once per replica, and each call mints a fresh [`Session`] (its
    /// own ping-pong/scratch buffers) from the SAME plan.  One compile,
    /// one weight set, one persistent thread pool, N sets of buffers.
    /// The plan's shape contract (input shape, class count, labels)
    /// rides along.
    pub fn from_plan(plan: &Plan) -> Self {
        Self {
            name: format!("native/{}", plan.kernel().name()),
            input_shape: plan.input_shape(),
            classes: plan.classes(),
            labels: plan.labels().map(<[String]>::to_vec),
            session: plan.session(),
        }
    }

    /// Default arm: the paper's kernel, shape-aware auto-dispatch (the
    /// plan resolves every op to the best impl for this CPU).
    pub fn xnor(engine: &BnnEngine, batch: usize) -> Self {
        Self::new(engine, EngineKernel::Xnor(XnorImpl::Auto), batch)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.session.max_batch()
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.input_shape
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn labels(&self) -> Option<&[String]> {
        self.labels.as_deref()
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        Ok(self.session.run(images))
    }
}

/// PJRT executable backend (fixed batch baked at AOT time).
pub struct PjrtBackend {
    name: String,
    model: LoadedModel,
    last: Tensor,
}

impl PjrtBackend {
    /// Wrap one loaded PJRT executable.
    pub fn new(model: LoadedModel) -> Self {
        Self {
            name: format!("pjrt/{}", model.name),
            model,
            last: Tensor::zeros(vec![1, 1]),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.model.batch
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.model.input_shape()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        self.last = self.model.infer(images)?;
        Ok(&self.last)
    }
}

/// Test stub: logits[i][c] = image mean * (c == target) with an optional
/// artificial delay, so tests can assert routing and batching without a
/// model.  Shape-configurable: [`MockBackend::with_shape`] mocks any
/// input geometry / class count (default: the paper's 3x32x32 / 10).
pub struct MockBackend {
    /// Batch capacity reported by `max_batch`.
    pub batch: usize,
    /// Per-image input shape (C, H, W) reported by `input_shape`.
    pub shape: (usize, usize, usize),
    /// Class count reported by `classes` (logit rows have this width).
    pub classes: usize,
    /// Optional label table reported by `labels`.
    pub labels: Option<Vec<String>>,
    /// Artificial per-batch latency.
    pub delay: std::time::Duration,
    /// Number of `infer` calls (shared, so replicated-router tests can
    /// aggregate across replicas).
    pub calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    name: String,
    out: Tensor,
}

impl MockBackend {
    /// A mock with `batch` capacity and `delay_ms` of artificial
    /// latency per batch, speaking the legacy 3x32x32/10-class shape.
    pub fn new(batch: usize, delay_ms: u64) -> Self {
        Self::with_shape(batch, delay_ms, (3, 32, 32), 10)
    }

    /// A mock speaking an arbitrary shape contract: `shape` images in,
    /// `classes` logits out.
    pub fn with_shape(
        batch: usize,
        delay_ms: u64,
        shape: (usize, usize, usize),
        classes: usize,
    ) -> Self {
        assert!(classes >= 1, "need at least one class");
        Self {
            batch,
            shape,
            classes,
            labels: None,
            delay: std::time::Duration::from_millis(delay_ms),
            calls: Default::default(),
            name: format!("mock/b{batch}"),
            out: Tensor::zeros(vec![1, 1]),
        }
    }

    /// [`MockBackend::new`] with an externally shared call counter —
    /// a replicated router constructs one backend per replica, so
    /// tests counting total `infer` calls share the counter up front.
    pub fn with_calls(
        batch: usize,
        delay_ms: u64,
        calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    ) -> Self {
        Self { calls, ..Self::new(batch, delay_ms) }
    }
}

impl Backend for MockBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.shape
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn labels(&self) -> Option<&[String]> {
        self.labels.as_deref()
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        self.calls
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let b = images.dim(0);
        let chw = images.len() / b;
        let nc = self.classes;
        self.out.reset(&[b, nc]);
        self.out.data_mut().fill(0.0);
        for i in 0..b {
            let mean: f32 = images.data()[i * chw..(i + 1) * chw]
                .iter()
                .sum::<f32>()
                / chw as f32;
            // Deterministic "class": scaled mean bucketed into 0..nc.
            let cls = (((mean + 1.0) / 2.0 * (nc as f32 - 0.01)) as usize)
                .min(nc - 1);
            self.out.data_mut()[i * nc + cls] = 1.0 + mean.abs();
        }
        Ok(&self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_backend_deterministic() {
        let mut m = MockBackend::new(4, 0);
        let x = Tensor::full(vec![2, 3, 32, 32], 0.5);
        let a = m.infer(&x).unwrap().clone();
        let b = m.infer(&x).unwrap().clone();
        assert_eq!(a, b);
        assert_eq!(m.calls.load(std::sync::atomic::Ordering::SeqCst), 2);
        assert_eq!(a.shape(), &[2, 10]);
    }

    #[test]
    fn mock_backend_shape_configurable() {
        let mut m = MockBackend::with_shape(2, 0, (1, 28, 28), 26);
        assert_eq!(m.input_shape(), (1, 28, 28));
        assert_eq!(m.classes(), 26);
        assert!(m.labels().is_none());
        let x = Tensor::full(vec![2, 1, 28, 28], 0.25);
        let out = m.infer(&x).unwrap();
        assert_eq!(out.shape(), &[2, 26]);
        m.labels = Some(vec!["x".into(); 26]);
        assert_eq!(m.labels().map(<[String]>::len), Some(26));
    }

    #[test]
    fn mock_class_tracks_mean() {
        let mut m = MockBackend::new(1, 0);
        let lo = m
            .infer(&Tensor::full(vec![1, 3, 32, 32], -0.9))
            .unwrap()
            .clone();
        let hi = m
            .infer(&Tensor::full(vec![1, 3, 32, 32], 0.9))
            .unwrap()
            .clone();
        let am = crate::nn::argmax(lo.row(0));
        let bm = crate::nn::argmax(hi.row(0));
        assert!(am < bm, "{am} vs {bm}");
    }
}
