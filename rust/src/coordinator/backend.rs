//! Inference backends the coordinator can drive.
//!
//! A [`Backend`] consumes a fixed-capacity image batch and returns
//! logits.  Three implementations:
//! * [`NativeBackend`] — the in-process rust engine (Table-2 CPU arm),
//! * [`PjrtBackend`]   — an AOT-compiled XLA executable (accelerator arm),
//! * [`MockBackend`]   — deterministic stub for coordinator tests.

use anyhow::Result;

use crate::bitops::XnorImpl;
use crate::model::{BnnEngine, EngineKernel};
use crate::nn::conv::ConvScratch;
use crate::runtime::LoadedModel;
use crate::tensor::Tensor;

/// A batched inference backend.  `infer` receives exactly
/// `max_batch()` images ([B, 3, 32, 32] normalized) — the worker pads
/// short batches — and returns logits [B, 10].
///
/// NOT `Send`: PJRT handles contain thread-affine state (`Rc`, raw
/// pointers), so the router constructs every backend INSIDE its worker
/// thread via a `Send` factory closure (see [`super::Router::start`]).
pub trait Backend {
    fn name(&self) -> String;
    fn max_batch(&self) -> usize;
    fn infer(&mut self, images: &Tensor) -> Result<Tensor>;
}

/// Native rust engine backend (any [`EngineKernel`] arm).
pub struct NativeBackend {
    engine: std::sync::Arc<BnnEngine>,
    kernel: EngineKernel,
    batch: usize,
    scratch: ConvScratch,
}

impl NativeBackend {
    pub fn new(
        engine: std::sync::Arc<BnnEngine>,
        kernel: EngineKernel,
        batch: usize,
    ) -> Self {
        Self { engine, kernel, batch, scratch: ConvScratch::default() }
    }

    /// Default arm: the paper's kernel, best native implementation.
    pub fn xnor(engine: std::sync::Arc<BnnEngine>, batch: usize) -> Self {
        Self::new(engine, EngineKernel::Xnor(XnorImpl::Blocked), batch)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        format!("native/{}", self.kernel.name())
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, images: &Tensor) -> Result<Tensor> {
        Ok(self
            .engine
            .forward_with_scratch(images, self.kernel, &mut self.scratch))
    }
}

/// PJRT executable backend (fixed batch baked at AOT time).
pub struct PjrtBackend {
    model: LoadedModel,
}

impl PjrtBackend {
    pub fn new(model: LoadedModel) -> Self {
        Self { model }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt/{}", self.model.name)
    }

    fn max_batch(&self) -> usize {
        self.model.batch
    }

    fn infer(&mut self, images: &Tensor) -> Result<Tensor> {
        self.model.infer(images)
    }
}

/// Test stub: logits[i][c] = image mean * (c == target) with an optional
/// artificial delay, so tests can assert routing and batching without a
/// model.
pub struct MockBackend {
    pub batch: usize,
    pub delay: std::time::Duration,
    pub calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
}

impl MockBackend {
    pub fn new(batch: usize, delay_ms: u64) -> Self {
        Self {
            batch,
            delay: std::time::Duration::from_millis(delay_ms),
            calls: Default::default(),
        }
    }
}

impl Backend for MockBackend {
    fn name(&self) -> String {
        format!("mock/b{}", self.batch)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, images: &Tensor) -> Result<Tensor> {
        self.calls
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let b = images.dim(0);
        let chw = images.len() / b;
        let mut out = vec![0.0f32; b * 10];
        for i in 0..b {
            let mean: f32 = images.data()[i * chw..(i + 1) * chw]
                .iter()
                .sum::<f32>()
                / chw as f32;
            // Deterministic "class": scaled mean bucketed into 0..10.
            let cls = (((mean + 1.0) / 2.0 * 9.99) as usize).min(9);
            out[i * 10 + cls] = 1.0 + mean.abs();
        }
        Ok(Tensor::new(vec![b, 10], out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_backend_deterministic() {
        let mut m = MockBackend::new(4, 0);
        let x = Tensor::full(vec![2, 3, 32, 32], 0.5);
        let a = m.infer(&x).unwrap();
        let b = m.infer(&x).unwrap();
        assert_eq!(a, b);
        assert_eq!(m.calls.load(std::sync::atomic::Ordering::SeqCst), 2);
        assert_eq!(a.shape(), &[2, 10]);
    }

    #[test]
    fn mock_class_tracks_mean() {
        let mut m = MockBackend::new(1, 0);
        let lo = m.infer(&Tensor::full(vec![1, 3, 32, 32], -0.9)).unwrap();
        let hi = m.infer(&Tensor::full(vec![1, 3, 32, 32], 0.9)).unwrap();
        let am = crate::nn::argmax(lo.row(0));
        let bm = crate::nn::argmax(hi.row(0));
        assert!(am < bm, "{am} vs {bm}");
    }
}
