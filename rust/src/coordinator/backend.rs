//! Inference backends the coordinator can drive.
//!
//! A [`Backend`] consumes a fixed-capacity image batch and returns
//! logits.  Three implementations:
//! * [`NativeBackend`] — the in-process rust engine (Table-2 CPU arm),
//!   a compiled [`Session`] over the requested kernel arm,
//! * [`PjrtBackend`]   — an AOT-compiled XLA executable (accelerator arm),
//! * [`MockBackend`]   — deterministic stub for coordinator tests.
//!
//! The trait is shaped for the request path: `name` borrows (metrics
//! labels allocate nothing) and `infer` returns the logits by reference
//! into backend-owned storage, so the native backend's inference step
//! itself allocates nothing in steady state.  (The router's worker loop
//! still allocates its padded input tensor and per-request reply rows —
//! see `router.rs` — so the zero-alloc guarantee is scoped to
//! `Session::run` inside `infer`.)

use anyhow::Result;

use crate::bitops::XnorImpl;
use crate::model::{BnnEngine, EngineKernel, Plan, Session};
use crate::runtime::LoadedModel;
use crate::tensor::Tensor;

/// A batched inference backend.  `infer` receives exactly
/// `max_batch()` images ([B, 3, 32, 32] normalized) — the worker pads
/// short batches — and returns logits [B, 10], valid until the next
/// `infer` call.
///
/// NOT `Send`: PJRT handles contain thread-affine state (`Rc`, raw
/// pointers), so the router constructs every backend INSIDE its
/// replica worker thread via a `Send + Sync` factory closure called
/// once per replica (see [`super::Router::start`]).
pub trait Backend {
    /// Stable label for logs and metrics (e.g. `native/xnor/auto`).
    fn name(&self) -> &str;
    /// Largest batch `infer` accepts (the worker pads up to it).
    fn max_batch(&self) -> usize;
    /// Run one padded batch; the returned logits borrow backend-owned
    /// storage and stay valid until the next call.
    fn infer(&mut self, images: &Tensor) -> Result<&Tensor>;
}

/// Native rust engine backend (any [`EngineKernel`] arm): a compiled
/// plan's [`Session`], so every request batch reuses the same buffers.
/// The engine itself is NOT retained — the plan shares its weights.
pub struct NativeBackend {
    name: String,
    session: Session,
}

impl NativeBackend {
    /// Compile a fresh plan for `(kernel, batch)` and back it with one
    /// session.  For a replica pool, prefer compiling once and calling
    /// [`NativeBackend::from_plan`] per replica.
    pub fn new(engine: &BnnEngine, kernel: EngineKernel, batch: usize)
               -> Self {
        Self {
            name: format!("native/{}", kernel.name()),
            session: engine
                .plan(kernel, batch)
                .expect("batch >= 1 and spec validated at load")
                .session(),
        }
    }

    /// Backend over an already-compiled, shared [`Plan`] — the
    /// replica-pool path: [`super::Router::start`] calls its factory
    /// once per replica, and each call mints a fresh [`Session`] (its
    /// own ping-pong/scratch buffers) from the SAME plan.  One compile,
    /// one weight set, one persistent thread pool, N sets of buffers.
    pub fn from_plan(plan: &Plan) -> Self {
        Self {
            name: format!("native/{}", plan.kernel().name()),
            session: plan.session(),
        }
    }

    /// Default arm: the paper's kernel, shape-aware auto-dispatch (the
    /// plan resolves every op to the best impl for this CPU).
    pub fn xnor(engine: &BnnEngine, batch: usize) -> Self {
        Self::new(engine, EngineKernel::Xnor(XnorImpl::Auto), batch)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.session.max_batch()
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        Ok(self.session.run(images))
    }
}

/// PJRT executable backend (fixed batch baked at AOT time).
pub struct PjrtBackend {
    name: String,
    model: LoadedModel,
    last: Tensor,
}

impl PjrtBackend {
    /// Wrap one loaded PJRT executable.
    pub fn new(model: LoadedModel) -> Self {
        Self {
            name: format!("pjrt/{}", model.name),
            model,
            last: Tensor::zeros(vec![1, 1]),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.model.batch
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        self.last = self.model.infer(images)?;
        Ok(&self.last)
    }
}

/// Test stub: logits[i][c] = image mean * (c == target) with an optional
/// artificial delay, so tests can assert routing and batching without a
/// model.
pub struct MockBackend {
    /// Batch capacity reported by `max_batch`.
    pub batch: usize,
    /// Artificial per-batch latency.
    pub delay: std::time::Duration,
    /// Number of `infer` calls (shared, so replicated-router tests can
    /// aggregate across replicas).
    pub calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    name: String,
    out: Tensor,
}

impl MockBackend {
    /// A mock with `batch` capacity and `delay_ms` of artificial
    /// latency per batch.
    pub fn new(batch: usize, delay_ms: u64) -> Self {
        Self {
            batch,
            delay: std::time::Duration::from_millis(delay_ms),
            calls: Default::default(),
            name: format!("mock/b{batch}"),
            out: Tensor::zeros(vec![1, 1]),
        }
    }

    /// [`MockBackend::new`] with an externally shared call counter —
    /// a replicated router constructs one backend per replica, so
    /// tests counting total `infer` calls share the counter up front.
    pub fn with_calls(
        batch: usize,
        delay_ms: u64,
        calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    ) -> Self {
        Self { calls, ..Self::new(batch, delay_ms) }
    }
}

impl Backend for MockBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, images: &Tensor) -> Result<&Tensor> {
        self.calls
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let b = images.dim(0);
        let chw = images.len() / b;
        self.out.reset(&[b, 10]);
        self.out.data_mut().fill(0.0);
        for i in 0..b {
            let mean: f32 = images.data()[i * chw..(i + 1) * chw]
                .iter()
                .sum::<f32>()
                / chw as f32;
            // Deterministic "class": scaled mean bucketed into 0..10.
            let cls = (((mean + 1.0) / 2.0 * 9.99) as usize).min(9);
            self.out.data_mut()[i * 10 + cls] = 1.0 + mean.abs();
        }
        Ok(&self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_backend_deterministic() {
        let mut m = MockBackend::new(4, 0);
        let x = Tensor::full(vec![2, 3, 32, 32], 0.5);
        let a = m.infer(&x).unwrap().clone();
        let b = m.infer(&x).unwrap().clone();
        assert_eq!(a, b);
        assert_eq!(m.calls.load(std::sync::atomic::Ordering::SeqCst), 2);
        assert_eq!(a.shape(), &[2, 10]);
    }

    #[test]
    fn mock_class_tracks_mean() {
        let mut m = MockBackend::new(1, 0);
        let lo = m
            .infer(&Tensor::full(vec![1, 3, 32, 32], -0.9))
            .unwrap()
            .clone();
        let hi = m
            .infer(&Tensor::full(vec![1, 3, 32, 32], 0.9))
            .unwrap()
            .clone();
        let am = crate::nn::argmax(lo.row(0));
        let bm = crate::nn::argmax(hi.row(0));
        assert!(am < bm, "{am} vs {bm}");
    }
}
