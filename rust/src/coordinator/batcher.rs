//! Dynamic batcher: max-size / max-delay batch formation.
//!
//! One batcher thread owns the request queue.  A batch closes when
//! `max_batch` requests are waiting, or `max_delay` has elapsed since
//! the FIRST request of the batch arrived — the standard serving
//! trade-off between throughput (big batches) and tail latency.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Batch-formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Close a batch once this many requests are waiting.
    pub max_batch: usize,
    /// ... or once the batch's FIRST request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_delay: Duration::from_millis(5) }
    }
}

/// Pulls from `rx` and yields closed batches.
pub struct DynamicBatcher<T> {
    rx: mpsc::Receiver<T>,
    cfg: BatcherConfig,
}

impl<T> DynamicBatcher<T> {
    /// Wrap a request receiver with a batch-formation policy.
    pub fn new(rx: mpsc::Receiver<T>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { rx, cfg }
    }

    /// Block until a batch forms; `None` when all senders are gone.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the batch's first element.
        let first = self.rx.recv().ok()?;
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.cfg.max_delay;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_delay: Duration::from_secs(5) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn closes_on_delay_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(10),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_when_senders_dropped() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(rx, BatcherConfig::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 3,
                max_delay: Duration::from_millis(200),
            },
        );
        let h = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(2).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(3).unwrap();
        });
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]); // closed by max_batch, not delay
    }

    #[test]
    fn partial_batch_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 5, max_delay: Duration::from_secs(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![7, 8]);
        assert!(b.next_batch().is_none());
    }
}
