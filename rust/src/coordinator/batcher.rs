//! Batch formation: the fixed max-size / max-delay batcher, its
//! continuous (replica-aware) successor, and the reusable padded batch
//! tensor replicas assemble requests into.
//!
//! One batcher thread owns the request queue.  With the fixed policy
//! ([`DynamicBatcher`]) a batch closes when `max_batch` requests are
//! waiting, or `max_delay` has elapsed since the FIRST request of the
//! batch arrived — the standard serving trade-off between throughput
//! (big batches) and tail latency.  Its weakness under load: once a
//! batch closes, the batcher blocks handing it to a replica slot, and
//! requests arriving during that wait cannot join it even though no
//! replica has started executing it yet.
//!
//! [`ContinuousBatcher`] removes that gap.  It keeps a batch **open
//! while every replica is busy**, admitting queued requests into it
//! (up to `max_batch`) right until the instant a replica frees — at
//! which point the batch dispatches immediately.  When replicas are
//! idle it degrades to exactly the fixed policy (`max_batch` /
//! `max_delay`), so low-load latency is unchanged; deadline,
//! backpressure, drain, and supervision semantics all live outside the
//! formation policy and are untouched.
//!
//! [`BatchBuffer`] is the worker-side counterpart: one preallocated
//! `[cap, C, H, W]` tensor per replica, sized from the backend's shape
//! contract, refilled in place for every dispatched batch (only the
//! stale padded tail is re-zeroed — no per-batch allocation).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// Batch-formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Close a batch once this many requests are waiting.
    pub max_batch: usize,
    /// ... or once the batch's FIRST request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_delay: Duration::from_millis(5) }
    }
}

/// Pulls from `rx` and yields closed batches.
pub struct DynamicBatcher<T> {
    rx: mpsc::Receiver<T>,
    cfg: BatcherConfig,
}

impl<T> DynamicBatcher<T> {
    /// Wrap a request receiver with a batch-formation policy.
    pub fn new(rx: mpsc::Receiver<T>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { rx, cfg }
    }

    /// Block until a batch forms; `None` when all senders are gone.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the batch's first element.
        let first = self.rx.recv().ok()?;
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.cfg.max_delay;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

/// Continuous batch formation: like [`DynamicBatcher`], but batch
/// closure is driven by replica availability, not only by size/delay.
///
/// The caller supplies a `replica_free` probe (any replica idle and
/// able to take a batch right now?).  Policy per call:
///
/// * **Batch full** — hand off immediately; the caller's blocking
///   slot send already wakes the moment a replica frees, so full
///   batches need no probe.
/// * **Partial batch, a replica free** — dispatch when the delay
///   window has expired or the batch ever had to wait for a replica
///   (`starved`); otherwise hold the window open exactly like the
///   fixed batcher so low-load batches still coalesce.
/// * **Partial batch, every replica busy** — keep admitting arrivals
///   into the open batch (up to `max_batch`) instead of closing it;
///   the batch goes out the instant a replica frees.  Requests beyond
///   `max_batch` stay in the bounded admission queue, so backpressure
///   ([`SubmitError::QueueFull`]) is exactly as before.
///
/// Drain semantics match [`DynamicBatcher`]: once all senders are
/// gone the pending batch (and then every still-buffered request) is
/// flushed before `next_batch` returns `None`, so shutdown never
/// drops an admitted request.
///
/// [`SubmitError::QueueFull`]: crate::coordinator::SubmitError::QueueFull
pub struct ContinuousBatcher<T> {
    rx: mpsc::Receiver<T>,
    cfg: BatcherConfig,
    pending: Vec<T>,
    first_at: Instant,
    /// The open batch observed an all-busy pool at least once; the
    /// moment a replica frees it should go out without waiting out
    /// the delay window.
    starved: bool,
    disconnected: bool,
}

/// Poll granularity while waiting for a replica to free (the probe is
/// a function, not a waitable handle).  Half a millisecond keeps the
/// added dispatch latency an order of magnitude under the default
/// 5 ms delay window while the batcher thread stays >99% asleep.
const FREE_POLL: Duration = Duration::from_micros(500);

impl<T> ContinuousBatcher<T> {
    /// Wrap a request receiver with a continuous-formation policy.
    pub fn new(rx: mpsc::Receiver<T>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self {
            rx,
            cfg,
            pending: Vec::with_capacity(cfg.max_batch),
            first_at: Instant::now(),
            starved: false,
            disconnected: false,
        }
    }

    fn take(&mut self) -> Vec<T> {
        self.starved = false;
        std::mem::replace(
            &mut self.pending,
            Vec::with_capacity(self.cfg.max_batch),
        )
    }

    fn push(&mut self, item: T) {
        if self.pending.is_empty() {
            self.first_at = Instant::now();
        }
        self.pending.push(item);
    }

    /// Top up the open batch from the queue without blocking.
    fn drain_ready(&mut self) {
        while self.pending.len() < self.cfg.max_batch {
            match self.rx.try_recv() {
                Ok(item) => self.push(item),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.disconnected = true;
                    break;
                }
            }
        }
    }

    /// Block until a batch should be dispatched; `None` once all
    /// senders are gone and every buffered request has been flushed.
    pub fn next_batch(
        &mut self,
        replica_free: impl Fn() -> bool,
    ) -> Option<Vec<T>> {
        loop {
            if self.pending.is_empty() {
                // Block for the batch's first element.
                match self.rx.recv() {
                    Ok(item) => self.push(item),
                    Err(_) => return None,
                }
            }
            self.drain_ready();
            if self.pending.len() >= self.cfg.max_batch || self.disconnected
            {
                // A full batch hands off immediately — the caller's
                // blocking slot send wakes the moment a replica
                // frees, which is as continuous as a full batch can
                // get.  Disconnect is the shutdown flush.
                return Some(self.take());
            }
            let expired = self.first_at.elapsed() >= self.cfg.max_delay;
            if replica_free() {
                if expired || self.starved {
                    return Some(self.take());
                }
                // Idle pool inside the delay window: coalesce exactly
                // like the fixed batcher.
                let deadline = self.first_at + self.cfg.max_delay;
                let wait = deadline
                    .saturating_duration_since(Instant::now())
                    .min(FREE_POLL);
                match self.rx.recv_timeout(wait) {
                    Ok(item) => self.push(item),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.disconnected = true;
                    }
                }
            } else {
                // Every replica busy: the continuous part.  Keep the
                // batch open and admit arrivals until one frees.
                self.starved = true;
                match self.rx.recv_timeout(FREE_POLL) {
                    Ok(item) => self.push(item),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.disconnected = true;
                    }
                }
            }
        }
    }
}

/// A replica's reusable padded input tensor: `[cap, C, H, W]`,
/// allocated once from the backend's shape contract and refilled in
/// place per batch.  Rows `0..b` hold the batch's images; rows
/// `b..cap` are the zero padding the backend contract requires.  Only
/// rows made stale by a previous (larger) batch are re-zeroed.
pub struct BatchBuffer {
    tensor: Tensor,
    chw: usize,
    cap: usize,
    /// Rows holding request data from the previous fill (everything
    /// past them is already zero).
    filled: usize,
}

impl BatchBuffer {
    /// Allocate the padded tensor for `cap` images of `shape`
    /// (C, H, W).
    pub fn new(cap: usize, shape: (usize, usize, usize)) -> Self {
        let (c, h, w) = shape;
        Self {
            tensor: Tensor::zeros(vec![cap, c, h, w]),
            chw: c * h * w,
            cap,
            filled: 0,
        }
    }

    /// Elements per image (`C*H*W`) — every row must have this length.
    pub fn image_elems(&self) -> usize {
        self.chw
    }

    /// Copy `rows` into rows `0..b`, zero the stale tail, and return
    /// the padded tensor.  Panics if `rows` exceeds capacity or any
    /// row has the wrong length (the router validated both upstream).
    pub fn fill<'a>(
        &mut self,
        rows: impl ExactSizeIterator<Item = &'a [f32]>,
    ) -> &Tensor {
        let b = rows.len();
        assert!(b <= self.cap, "batch {b} exceeds capacity {}", self.cap);
        let data = self.tensor.data_mut();
        for (i, row) in rows.enumerate() {
            data[i * self.chw..(i + 1) * self.chw].copy_from_slice(row);
        }
        if self.filled > b {
            data[b * self.chw..self.filled * self.chw].fill(0.0);
        }
        self.filled = b;
        &self.tensor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_buffer_reuses_and_zeroes_only_stale_tail() {
        let mut buf = BatchBuffer::new(4, (1, 2, 2));
        assert_eq!(buf.image_elems(), 4);
        let a = vec![1.0f32; 4];
        let b = vec![2.0f32; 4];
        let ptr = {
            let t = buf.fill([&a[..], &b[..]].into_iter());
            assert_eq!(t.shape(), &[4, 1, 2, 2]);
            assert_eq!(&t.data()[..8],
                       &[1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
            assert!(t.data()[8..].iter().all(|&v| v == 0.0));
            t.data().as_ptr() as usize
        };
        // A smaller follow-up batch must zero the now-stale row 1 and
        // reuse the same allocation.
        let c = vec![3.0f32; 4];
        let t = buf.fill([&c[..]].into_iter());
        assert_eq!(&t.data()[..4], &[3.0; 4]);
        assert!(t.data()[4..].iter().all(|&v| v == 0.0));
        assert_eq!(t.data().as_ptr() as usize, ptr, "buffer reallocated");
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn batch_buffer_rejects_overfull_batches() {
        let mut buf = BatchBuffer::new(1, (1, 1, 1));
        let r = [0.0f32];
        buf.fill([&r[..], &r[..]].into_iter());
    }

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_delay: Duration::from_secs(5) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn closes_on_delay_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(10),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_when_senders_dropped() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(rx, BatcherConfig::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 3,
                max_delay: Duration::from_millis(200),
            },
        );
        let h = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(2).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(3).unwrap();
        });
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]); // closed by max_batch, not delay
    }

    #[test]
    fn partial_batch_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 5, max_delay: Duration::from_secs(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![7, 8]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn continuous_matches_fixed_when_replicas_idle() {
        // With a free replica and no starvation, the continuous policy
        // is the fixed one: full batches go out without waiting...
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut b = ContinuousBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_delay: Duration::from_secs(5) },
        );
        assert_eq!(b.next_batch(|| true).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch(|| true).unwrap(), vec![4, 5, 6, 7]);
        // ...and a partial batch waits out the delay window.
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let mut b = ContinuousBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(10),
            },
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch(|| true).unwrap(), vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
        drop(tx);
        assert!(b.next_batch(|| true).is_none());
    }

    #[test]
    fn continuous_admits_arrivals_while_replicas_busy() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        // A replica frees 50ms in; requests trickling during the busy
        // period must all ride the SAME batch even though the 5ms
        // delay window expires long before dispatch.
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let free = Arc::new(AtomicBool::new(false));
        let free2 = Arc::clone(&free);
        let sender = std::thread::spawn(move || {
            for i in 1..4 {
                std::thread::sleep(Duration::from_millis(10));
                tx.send(i).unwrap();
            }
            std::thread::sleep(Duration::from_millis(10));
            free2.store(true, Ordering::SeqCst);
            tx // keep the channel alive past the assertion
        });
        let mut b = ContinuousBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(5),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch(|| free.load(Ordering::SeqCst)).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(
            t0.elapsed() >= Duration::from_millis(35),
            "must have held the batch open until the replica freed"
        );
        drop(sender.join().unwrap());
        assert!(b.next_batch(|| true).is_none());
    }

    #[test]
    fn continuous_starved_batch_dispatches_the_instant_a_replica_frees() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // The probe flips to free on its 3rd call; a starved batch
        // must not then wait out its (already long-expired) window.
        let (tx, rx) = mpsc::channel();
        tx.send(9).unwrap();
        let mut b = ContinuousBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_secs(10),
            },
        );
        let calls = AtomicUsize::new(0);
        let batch = b
            .next_batch(|| calls.fetch_add(1, Ordering::SeqCst) >= 2)
            .unwrap();
        assert_eq!(batch, vec![9]);
        drop(tx);
    }

    #[test]
    fn continuous_flushes_everything_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut b = ContinuousBatcher::new(
            rx,
            BatcherConfig { max_batch: 2, max_delay: Duration::from_secs(1) },
        );
        // Even with every replica busy forever, shutdown drains: no
        // admitted request may be stranded.
        let mut got = Vec::new();
        while let Some(batch) = b.next_batch(|| false) {
            assert!(batch.len() <= 2);
            got.extend(batch);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }
}
