//! Dynamic batcher: max-size / max-delay batch formation, plus the
//! reusable padded batch tensor replicas assemble requests into.
//!
//! One batcher thread owns the request queue.  A batch closes when
//! `max_batch` requests are waiting, or `max_delay` has elapsed since
//! the FIRST request of the batch arrived — the standard serving
//! trade-off between throughput (big batches) and tail latency.
//!
//! [`BatchBuffer`] is the worker-side counterpart: one preallocated
//! `[cap, C, H, W]` tensor per replica, sized from the backend's shape
//! contract, refilled in place for every dispatched batch (only the
//! stale padded tail is re-zeroed — no per-batch allocation).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// Batch-formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Close a batch once this many requests are waiting.
    pub max_batch: usize,
    /// ... or once the batch's FIRST request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_delay: Duration::from_millis(5) }
    }
}

/// Pulls from `rx` and yields closed batches.
pub struct DynamicBatcher<T> {
    rx: mpsc::Receiver<T>,
    cfg: BatcherConfig,
}

impl<T> DynamicBatcher<T> {
    /// Wrap a request receiver with a batch-formation policy.
    pub fn new(rx: mpsc::Receiver<T>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { rx, cfg }
    }

    /// Block until a batch forms; `None` when all senders are gone.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the batch's first element.
        let first = self.rx.recv().ok()?;
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.cfg.max_delay;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

/// A replica's reusable padded input tensor: `[cap, C, H, W]`,
/// allocated once from the backend's shape contract and refilled in
/// place per batch.  Rows `0..b` hold the batch's images; rows
/// `b..cap` are the zero padding the backend contract requires.  Only
/// rows made stale by a previous (larger) batch are re-zeroed.
pub struct BatchBuffer {
    tensor: Tensor,
    chw: usize,
    cap: usize,
    /// Rows holding request data from the previous fill (everything
    /// past them is already zero).
    filled: usize,
}

impl BatchBuffer {
    /// Allocate the padded tensor for `cap` images of `shape`
    /// (C, H, W).
    pub fn new(cap: usize, shape: (usize, usize, usize)) -> Self {
        let (c, h, w) = shape;
        Self {
            tensor: Tensor::zeros(vec![cap, c, h, w]),
            chw: c * h * w,
            cap,
            filled: 0,
        }
    }

    /// Elements per image (`C*H*W`) — every row must have this length.
    pub fn image_elems(&self) -> usize {
        self.chw
    }

    /// Copy `rows` into rows `0..b`, zero the stale tail, and return
    /// the padded tensor.  Panics if `rows` exceeds capacity or any
    /// row has the wrong length (the router validated both upstream).
    pub fn fill<'a>(
        &mut self,
        rows: impl ExactSizeIterator<Item = &'a [f32]>,
    ) -> &Tensor {
        let b = rows.len();
        assert!(b <= self.cap, "batch {b} exceeds capacity {}", self.cap);
        let data = self.tensor.data_mut();
        for (i, row) in rows.enumerate() {
            data[i * self.chw..(i + 1) * self.chw].copy_from_slice(row);
        }
        if self.filled > b {
            data[b * self.chw..self.filled * self.chw].fill(0.0);
        }
        self.filled = b;
        &self.tensor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_buffer_reuses_and_zeroes_only_stale_tail() {
        let mut buf = BatchBuffer::new(4, (1, 2, 2));
        assert_eq!(buf.image_elems(), 4);
        let a = vec![1.0f32; 4];
        let b = vec![2.0f32; 4];
        let ptr = {
            let t = buf.fill([&a[..], &b[..]].into_iter());
            assert_eq!(t.shape(), &[4, 1, 2, 2]);
            assert_eq!(&t.data()[..8],
                       &[1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
            assert!(t.data()[8..].iter().all(|&v| v == 0.0));
            t.data().as_ptr() as usize
        };
        // A smaller follow-up batch must zero the now-stale row 1 and
        // reuse the same allocation.
        let c = vec![3.0f32; 4];
        let t = buf.fill([&c[..]].into_iter());
        assert_eq!(&t.data()[..4], &[3.0; 4]);
        assert!(t.data()[4..].iter().all(|&v| v == 0.0));
        assert_eq!(t.data().as_ptr() as usize, ptr, "buffer reallocated");
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn batch_buffer_rejects_overfull_batches() {
        let mut buf = BatchBuffer::new(1, (1, 1, 1));
        let r = [0.0f32];
        buf.fill([&r[..], &r[..]].into_iter());
    }

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_delay: Duration::from_secs(5) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn closes_on_delay_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(10),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_when_senders_dropped() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(rx, BatcherConfig::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig {
                max_batch: 3,
                max_delay: Duration::from_millis(200),
            },
        );
        let h = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(2).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            tx.send(3).unwrap();
        });
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]); // closed by max_batch, not delay
    }

    #[test]
    fn partial_batch_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 5, max_delay: Duration::from_secs(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![7, 8]);
        assert!(b.next_batch().is_none());
    }
}
