//! bitkernel — CLI entry point for the serving coordinator.
//!
//! Subcommands:
//! * `serve`    — run the HTTP inference service
//! * `mount`    — mount a model on a running server (admin API client)
//! * `unmount`  — unmount a model on a running server
//! * `reload`   — reload a mounted model from its weight path
//! * `classify` — classify test-set images from the command line
//! * `eval`     — accuracy of a weight file over the test split
//! * `describe` — print a weight file's NetSpec, plan, and buffers
//! * `inspect`  — summarize the artifact manifest
//! * `selftest` — verify the three Table-2 arms agree end-to-end

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use bitkernel::bitops::XnorImpl;
use bitkernel::cli::{render_help, take_positional, Args, FlagSpec};
use bitkernel::coordinator::{
    Backend, BatcherConfig, NativeBackend, PjrtBackend, Router,
    RouterConfig,
};
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::runtime::Runtime;
use bitkernel::server::{
    http_call_retry, serve, ModelRegistry, ModelState, RegistryConfig,
    ServeOptions, Service,
};
use bitkernel::utils::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "mount" => cmd_mount(rest),
        "unmount" => cmd_unmount(rest),
        "reload" => cmd_reload(rest),
        "classify" => cmd_classify(rest),
        "eval" => cmd_eval(rest),
        "describe" => cmd_describe(rest),
        "inspect" => cmd_inspect(rest),
        "selftest" => cmd_selftest(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `bitkernel help`)"),
    }
}

fn print_usage() {
    println!(
        "bitkernel — XNOR-bitcount BNN inference stack\n\n\
         usage: bitkernel <subcommand> [flags]\n\n\
         subcommands:\n\
         \x20 serve     run the HTTP inference service\n\
         \x20 mount     mount a model on a running server (--admin)\n\
         \x20 unmount   unmount a model on a running server\n\
         \x20 reload    reload a mounted model from its weight path\n\
         \x20 classify  classify test-set images\n\
         \x20 eval      accuracy over the test split\n\
         \x20 describe  print a weight file's NetSpec, plan + buffers\n\
         \x20 inspect   summarize the artifact manifest\n\
         \x20 selftest  verify all kernel arms agree\n\n\
         run `bitkernel <subcommand> --help` for flags"
    );
}

const COMMON: [FlagSpec; 2] = [
    FlagSpec { name: "artifacts", takes_value: true,
               default: Some("artifacts"),
               help: "artifacts directory (make artifacts)" },
    FlagSpec { name: "help", takes_value: false, default: None,
               help: "show this help" },
];

fn parse_kernel(name: &str) -> Result<EngineKernel> {
    Ok(match name {
        // Default arm: shape-aware auto-dispatch at plan time.
        "xnor" | "xnor-auto" => EngineKernel::Xnor(XnorImpl::Auto),
        "xnor-blocked" => EngineKernel::Xnor(XnorImpl::Blocked),
        "xnor-blocked2x4" => EngineKernel::Xnor(XnorImpl::Blocked2x4),
        "xnor-scalar" | "xnor-scalar32" => {
            EngineKernel::Xnor(XnorImpl::Scalar)
        }
        "xnor-word64" => EngineKernel::Xnor(XnorImpl::Word64),
        // Both the flag spelling and the impl's reported label work.
        "xnor-wide" | "xnor-wide64" => EngineKernel::Xnor(XnorImpl::Wide),
        "xnor-simd" => EngineKernel::Xnor(XnorImpl::Simd),
        // Safe everywhere: falls back through AVX512BW/AVX2/wide when
        // VPOPCNTDQ is absent.
        "xnor-avx512" => EngineKernel::Xnor(XnorImpl::Avx512),
        "control" => EngineKernel::Control,
        "optimized" => EngineKernel::Optimized,
        other => {
            // xnor-threaded<N>: explicit 2-D tiled threading width.
            if let Some(t) = other.strip_prefix("xnor-threaded") {
                match t.parse::<usize>() {
                    Ok(t) if t >= 1 => {
                        return Ok(EngineKernel::Xnor(
                            XnorImpl::Threaded(t),
                        ));
                    }
                    _ => bail!("bad thread count in kernel '{other}'"),
                }
            }
            bail!("unknown kernel '{other}'")
        }
    })
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn cmd_serve(argv: &[String]) -> Result<()> {
    let specs = [
        COMMON[0].clone(),
        FlagSpec { name: "addr", takes_value: true,
                   default: Some("127.0.0.1:8080"), help: "bind address" },
        FlagSpec { name: "backend", takes_value: true,
                   default: Some("native-xnor"),
                   help: "native-{xnor,control,optimized} or pjrt-{xnor,control,optimized}" },
        FlagSpec { name: "weights", takes_value: true, default: Some("small"),
                   help: "weight set: small (trained) or full" },
        FlagSpec { name: "model", takes_value: true, default: None,
                   help: "serve a weight file as <name>=<path.bkw> \
                          (repeatable — heterogeneous shapes/classes \
                          welcome; first one is the default model; \
                          native backends only; overrides --weights)" },
        FlagSpec { name: "batch", takes_value: true, default: Some("8"),
                   help: "max dynamic batch size" },
        FlagSpec { name: "max-delay-ms", takes_value: true, default: Some("5"),
                   help: "batch formation deadline" },
        FlagSpec { name: "queue-cap", takes_value: true, default: Some("256"),
                   help: "admission queue capacity" },
        FlagSpec { name: "replicas", takes_value: true, default: Some("0"),
                   help: "worker replicas sharing one compiled plan \
                          (0 = one per core, capped at 8)" },
        FlagSpec { name: "threads", takes_value: true, default: Some("4"),
                   help: "HTTP handler threads" },
        FlagSpec { name: "max-connections", takes_value: true,
                   default: Some("256"),
                   help: "open-connection cap (accepts past it answer \
                          503 + Retry-After and close)" },
        FlagSpec { name: "idle-timeout-ms", takes_value: true,
                   default: Some("30000"),
                   help: "close connections idle longer than this \
                          (both front ends)" },
        FlagSpec { name: "event-loop", takes_value: false, default: None,
                   help: "serve with the non-blocking epoll front end \
                          (linux; scales past the handler pool)" },
        FlagSpec { name: "io-threads", takes_value: true,
                   default: Some("1"),
                   help: "reactor threads for --event-loop" },
        FlagSpec { name: "admin", takes_value: false, default: None,
                   help: "enable the mutating admin API (POST/PUT/DELETE \
                          /models) for live mount/reload/unmount" },
        FlagSpec { name: "lazy", takes_value: false, default: None,
                   help: "mount --model entries cold: map weights now, \
                          compile on first request" },
        FlagSpec { name: "max-resident", takes_value: true,
                   default: Some("0"),
                   help: "LRU-demote compiled pipelines beyond this many \
                          models (0 = unlimited)" },
        FlagSpec { name: "numa", takes_value: false, default: None,
                   help: "pin replica workers round-robin across NUMA \
                          nodes (sysfs topology; first-touch places \
                          each replica's buffers on its node)" },
        COMMON[1].clone(),
    ];
    let args = Args::parse(argv, &specs)?;
    if args.has("help") {
        print!("{}", render_help("serve", "run the HTTP service", &specs));
        return Ok(());
    }
    // Fault-injection drills: BITKERNEL_CHAOS holds a FaultPlan spec
    // (e.g. 'panic=0@3;delay_ms=20;fail_reads=1'), installed for the
    // process lifetime so chaos harnesses can exercise a real binary.
    if let Ok(spec) = std::env::var("BITKERNEL_CHAOS") {
        if !spec.trim().is_empty() {
            let plan = bitkernel::testing::chaos::FaultPlan::from_env(&spec)
                .context("parsing BITKERNEL_CHAOS")?;
            std::mem::forget(plan.install());
            bitkernel::log_warn!("chaos fault plan installed: '{spec}'");
        }
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let backend = args.get_or("backend", "native-xnor").to_string();
    let weights = args.get_or("weights", "small").to_string();
    let batch = args.get_usize("batch", 8)?;
    let delay = args.get_usize("max-delay-ms", 5)?;
    let replicas = match args.get_usize("replicas", 0)? {
        0 => bitkernel::coordinator::default_replicas(),
        n => n,
    };
    let cfg = RouterConfig {
        queue_cap: args.get_usize("queue-cap", 256)?,
        replicas,
        batcher: BatcherConfig {
            max_batch: batch,
            max_delay: std::time::Duration::from_millis(delay as u64),
        },
        numa_policy: if args.has("numa") {
            bitkernel::coordinator::NumaPolicy::RoundRobin
        } else {
            bitkernel::coordinator::NumaPolicy::Off
        },
    };

    // Two ways to populate the registry: repeated `--model
    // name=path.bkw` (heterogeneous shapes/classes behind one port), or
    // the legacy single-model `--backend`/`--weights` pair as "bnn".
    // With --admin the set stays editable over HTTP afterwards.
    let model_flags = args.get_all("model");
    let kernel = match backend.strip_prefix("native-") {
        Some(k) => parse_kernel(k)?,
        None if model_flags.is_empty() => {
            // Legacy pjrt path: the kernel only matters for models
            // mounted later over the admin API.
            EngineKernel::Xnor(XnorImpl::Auto)
        }
        None => bail!(
            "--model serves through the native engine; \
             got --backend {backend} (pjrt models go through \
             --weights and the artifact manifest)"
        ),
    };
    let registry = ModelRegistry::new(RegistryConfig {
        kernel,
        max_batch: batch,
        router: cfg,
        max_resident: args.get_usize("max-resident", 0)?,
    });
    let default_model = if model_flags.is_empty() {
        let router =
            start_backend(&artifacts, &backend, &weights, batch, cfg)?;
        registry
            .insert_router("bnn", router)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        "bnn".to_string()
    } else {
        let lazy = args.has("lazy");
        let mut entries = Vec::new();
        for spec in model_flags {
            let Some((name, path)) = spec.split_once('=') else {
                bail!("--model wants <name>=<path.bkw>, got '{spec}'");
            };
            anyhow::ensure!(!name.is_empty(), "--model name is empty");
            let entry = registry
                .mount(name, path, lazy)
                .map_err(|e| anyhow::anyhow!("{e}"))
                .with_context(|| format!("mounting model '{name}'"))?;
            entries.push(entry);
        }
        // The builds run off-thread; surface startup failures here so
        // `serve` fails fast exactly like the pre-registry loader.
        for entry in &entries {
            let st =
                entry.wait_settled(std::time::Duration::from_secs(300));
            if st.state == ModelState::Failed {
                bail!(
                    "loading model '{}': {}",
                    entry.name(),
                    st.error.unwrap_or_else(|| "build failed".into())
                );
            }
        }
        entries[0].name().to_string()
    };
    let service = Arc::new(Service::with_registry(
        registry,
        Some(default_model),
        args.has("admin"),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    serve(
        service,
        &ServeOptions {
            addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
            threads: args.get_usize("threads", 4)?,
            max_connections: args.get_usize("max-connections", 256)?,
            idle_timeout: std::time::Duration::from_millis(
                args.get_usize("idle-timeout-ms", 30_000)? as u64,
            ),
            event_loop: args.has("event-loop"),
            io_threads: args.get_usize("io-threads", 1)?,
        },
        stop,
        None,
    )
}

/// Wire up one replica pool per the `--backend` spec string.
fn start_backend(
    artifacts: &str,
    backend: &str,
    weights: &str,
    batch: usize,
    cfg: RouterConfig,
) -> Result<Router> {
    let artifacts = artifacts.to_string();
    let weights_name = weights.to_string();
    match backend {
        b if b.starts_with("native-") => {
            let kernel = parse_kernel(&b["native-".len()..])?;
            // Compile ONCE on the startup path; every replica mints its
            // own session (own buffers) from this shared plan.  The
            // engine itself need not outlive plan compilation — the
            // plan Arc-shares its weights.
            let manifest = bitkernel::runtime::Manifest::load(&artifacts)?;
            let path = manifest.weight_file(&weights_name)?;
            let engine = BnnEngine::load(path)?;
            // Any validated NetSpec serves: the router captures the
            // plan's shape contract and the HTTP layer derives the
            // request schema from it.
            let plan = engine.plan(kernel, batch)?;
            Router::start(
                move |_replica| {
                    Ok(Box::new(NativeBackend::from_plan(&plan))
                        as Box<dyn Backend>)
                },
                cfg,
            )
        }
        b if b.starts_with("pjrt-") => {
            let variant = b["pjrt-".len()..].to_string();
            // PJRT handles are thread-affine: each replica compiles its
            // own executable inside its worker thread.
            Router::start(
                move |_replica| {
                    let mut rt = Runtime::new(&artifacts)?;
                    let name = rt
                        .manifest
                        .find_model(&weights_name, &variant, batch)?
                        .name
                        .clone();
                    rt.load_model(&name)?;
                    let model = rt.take_model(&name)?;
                    Ok(Box::new(PjrtBackend::new(model)) as Box<dyn Backend>)
                },
                cfg,
            )
        }
        other => bail!("unknown backend '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// mount / unmount / reload — admin API clients
// ---------------------------------------------------------------------------

/// Flags shared by the three admin-client subcommands.
const ADMIN_CLIENT: [FlagSpec; 4] = [
    FlagSpec { name: "addr", takes_value: true,
               default: Some("127.0.0.1:8080"),
               help: "server address (needs serve --admin)" },
    FlagSpec { name: "no-wait", takes_value: false, default: None,
               help: "return 202 immediately instead of waiting for \
                      the build (poll GET /models/<name>)" },
    FlagSpec { name: "retries", takes_value: true, default: Some("3"),
               help: "retries (jittered backoff) when the server is \
                      unreachable — e.g. still starting up" },
    FlagSpec { name: "help", takes_value: false, default: None,
               help: "show this help" },
];

/// Issue one admin call and surface the server's JSON verbatim; any
/// status >= 300 becomes a non-zero exit.  Transient transport errors
/// (server still binding, connection dropped) are retried with
/// jittered backoff up to `retries` times.
fn admin_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    retries: usize,
) -> Result<()> {
    let (status, reply) = http_call_retry(addr, method, path, body, retries)?;
    println!("{}", String::from_utf8_lossy(&reply).trim_end());
    anyhow::ensure!(
        status < 300,
        "{method} {path} -> HTTP {status}"
    );
    Ok(())
}

/// `bitkernel mount <name>=<path.bkw> [--addr a] [--lazy] [--no-wait]`
fn cmd_mount(argv: &[String]) -> Result<()> {
    let (pos, flags) = take_positional(argv);
    let specs = [
        ADMIN_CLIENT[0].clone(),
        FlagSpec { name: "lazy", takes_value: false, default: None,
                   help: "map weights now, compile on first request" },
        ADMIN_CLIENT[1].clone(),
        ADMIN_CLIENT[2].clone(),
        ADMIN_CLIENT[3].clone(),
    ];
    let args = Args::parse(&flags, &specs)?;
    if args.has("help") {
        print!("{}", render_help(
            "mount",
            "mount a model on a running server \
             (usage: bitkernel mount <name>=<path.bkw>)",
            &specs,
        ));
        return Ok(());
    }
    let Some(spec) = pos else {
        bail!("mount wants a positional <name>=<path.bkw>");
    };
    let Some((name, path)) = spec.split_once('=') else {
        bail!("mount wants <name>=<path.bkw>, got '{spec}'");
    };
    anyhow::ensure!(!name.is_empty(), "model name is empty");
    // The server resolves the path from ITS working directory — send
    // an absolute path so `bitkernel mount m=./local.bkw` just works.
    let path = std::fs::canonicalize(path)
        .with_context(|| format!("resolving weight path '{path}'"))?;
    let body = Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("path", Json::Str(path.display().to_string())),
        ("lazy", Json::Bool(args.has("lazy"))),
    ])
    .to_string();
    let route =
        if args.has("no-wait") { "/models" } else { "/models?wait=1" };
    admin_call(
        args.get_or("addr", "127.0.0.1:8080"),
        "POST",
        route,
        body.as_bytes(),
        args.get_usize("retries", 3)?,
    )
}

/// `bitkernel unmount <name> [--addr a]`
fn cmd_unmount(argv: &[String]) -> Result<()> {
    let (pos, flags) = take_positional(argv);
    let specs = [
        ADMIN_CLIENT[0].clone(),
        ADMIN_CLIENT[2].clone(),
        ADMIN_CLIENT[3].clone(),
    ];
    let args = Args::parse(&flags, &specs)?;
    if args.has("help") {
        print!("{}", render_help(
            "unmount",
            "unmount a model on a running server \
             (usage: bitkernel unmount <name>)",
            &specs,
        ));
        return Ok(());
    }
    let Some(name) = pos else {
        bail!("unmount wants a positional <name>");
    };
    admin_call(
        args.get_or("addr", "127.0.0.1:8080"),
        "DELETE",
        &format!("/models/{name}"),
        b"",
        args.get_usize("retries", 3)?,
    )
}

/// `bitkernel reload <name> [--addr a] [--no-wait]`
fn cmd_reload(argv: &[String]) -> Result<()> {
    let (pos, flags) = take_positional(argv);
    let specs = [
        ADMIN_CLIENT[0].clone(),
        ADMIN_CLIENT[1].clone(),
        ADMIN_CLIENT[2].clone(),
        ADMIN_CLIENT[3].clone(),
    ];
    let args = Args::parse(&flags, &specs)?;
    if args.has("help") {
        print!("{}", render_help(
            "reload",
            "reload a mounted model from its weight path \
             (usage: bitkernel reload <name>)",
            &specs,
        ));
        return Ok(());
    }
    let Some(name) = pos else {
        bail!("reload wants a positional <name>");
    };
    let route = if args.has("no-wait") {
        format!("/models/{name}")
    } else {
        format!("/models/{name}?wait=1")
    };
    admin_call(
        args.get_or("addr", "127.0.0.1:8080"),
        "PUT",
        &route,
        b"",
        args.get_usize("retries", 3)?,
    )
}

// ---------------------------------------------------------------------------
// classify / eval / inspect / selftest
// ---------------------------------------------------------------------------

fn cmd_classify(argv: &[String]) -> Result<()> {
    let specs = [
        COMMON[0].clone(),
        FlagSpec { name: "index", takes_value: true, default: Some("0"),
                   help: "first test-set image index" },
        FlagSpec { name: "count", takes_value: true, default: Some("8"),
                   help: "number of images" },
        FlagSpec { name: "kernel", takes_value: true, default: Some("xnor"),
                   help: "xnor(-auto)|xnor-avx512|xnor-simd|xnor-wide|\
                          xnor-blocked|xnor-blocked2x4|xnor-scalar|\
                          xnor-word64|xnor-threaded<n>|control|optimized" },
        FlagSpec { name: "weights", takes_value: true, default: Some("small"),
                   help: "weight set" },
        COMMON[1].clone(),
    ];
    let args = Args::parse(argv, &specs)?;
    if args.has("help") {
        print!("{}", render_help("classify", "classify test images", &specs));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let ds = Dataset::load(dir.join("dataset_test.bin"))?;
    let weights = format!("weights_{}.bkw", args.get_or("weights", "small"));
    let engine = BnnEngine::load(dir.join(weights))?;
    let kernel = parse_kernel(args.get_or("kernel", "xnor"))?;
    let lo = args.get_usize("index", 0)?;
    let n = args.get_usize("count", 8)?.min(ds.count - lo);
    let x = ds.normalized(lo, lo + n);
    let preds = engine.predict(&x, kernel);
    println!("kernel: {}", kernel.name());
    // Class names from the weight file's label table; label-less
    // files print numeric classes.
    let label = |c: usize| engine.label_for(c);
    let mut correct = 0;
    for (i, p) in preds.iter().enumerate() {
        let truth = ds.labels[lo + i] as usize;
        let mark = if *p == truth { "ok " } else { "MISS" };
        if *p == truth {
            correct += 1;
        }
        println!(
            "image {:>5}  pred {:<13} truth {:<13} {}",
            lo + i,
            label(*p),
            label(truth),
            mark
        );
    }
    println!("{correct}/{n} correct");
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let specs = [
        COMMON[0].clone(),
        FlagSpec { name: "count", takes_value: true, default: Some("1024"),
                   help: "number of test images" },
        FlagSpec { name: "kernel", takes_value: true, default: Some("xnor"),
                   help: "kernel arm" },
        FlagSpec { name: "weights", takes_value: true, default: Some("small"),
                   help: "weight set" },
        FlagSpec { name: "batch", takes_value: true, default: Some("32"),
                   help: "eval batch size" },
        COMMON[1].clone(),
    ];
    let args = Args::parse(argv, &specs)?;
    if args.has("help") {
        print!("{}", render_help("eval", "test-split accuracy", &specs));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let ds = Dataset::load(dir.join("dataset_test.bin"))?;
    let weights = format!("weights_{}.bkw", args.get_or("weights", "small"));
    let engine = BnnEngine::load(dir.join(weights))?;
    let kernel = parse_kernel(args.get_or("kernel", "xnor"))?;
    let n = args.get_usize("count", 1024)?.min(ds.count);
    let x = ds.normalized(0, n);
    let sw = bitkernel::utils::Stopwatch::start();
    let acc = engine.evaluate(&x, &ds.labels[..n], kernel,
                              args.get_usize("batch", 32)?);
    println!(
        "kernel {}  images {n}  accuracy {:.4}  ({:.2}s, {:.1} img/s)",
        kernel.name(),
        acc,
        sw.elapsed_secs(),
        n as f64 / sw.elapsed_secs()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// describe
// ---------------------------------------------------------------------------

/// `bitkernel describe <weights.bkw> [--kernel k] [--batch n]`, or
/// `--weights <set>` to resolve through the artifacts dir.  Prints the
/// parsed NetSpec (op table with shapes and weight-key names), the
/// compiled plan's stage names with resolved Auto kernel choices, and
/// the per-session buffer footprint.
fn cmd_describe(argv: &[String]) -> Result<()> {
    // One optional positional: the weight-file path.
    let (file, flags) = take_positional(argv);
    let specs = [
        COMMON[0].clone(),
        FlagSpec { name: "weights", takes_value: true, default: None,
                   help: "weight set in the artifacts dir (alternative \
                          to the positional path)" },
        FlagSpec { name: "kernel", takes_value: true, default: Some("xnor"),
                   help: "kernel arm to compile the plan for" },
        FlagSpec { name: "batch", takes_value: true, default: Some("8"),
                   help: "max_batch the plan is sized for" },
        COMMON[1].clone(),
    ];
    let args = Args::parse(&flags, &specs)?;
    if args.has("help") {
        print!("{}", render_help(
            "describe",
            "print a weight file's NetSpec, plan, and session buffers \
             (usage: bitkernel describe <weights.bkw>)",
            &specs,
        ));
        return Ok(());
    }
    let path = match (file, args.get("weights")) {
        (Some(p), _) => std::path::PathBuf::from(p),
        (None, Some(set)) => {
            std::path::PathBuf::from(args.get_or("artifacts", "artifacts"))
                .join(format!("weights_{set}.bkw"))
        }
        (None, None) => anyhow::bail!(
            "describe needs a weight file: a positional path or --weights"
        ),
    };
    let wf = bitkernel::model::WeightFile::load(&path)?;
    let spec = wf.net_spec()?;
    let (ic, ih, iw) = spec.input();
    println!("file: {}", path.display());
    println!(
        "format: BKW{} ({})",
        wf.version(),
        if wf.version() == 2 {
            "spec embedded"
        } else {
            "legacy; spec synthesized from meta.widths"
        }
    );
    println!(
        "input {ic}x{ih}x{iw}  classes {}  params {}  tensors {}",
        spec.classes(),
        spec.param_count(),
        wf.len()
    );
    println!("scheme: {}", spec.scheme().name());
    match wf.labels() {
        Some(labels) => {
            println!("labels: {}", labels.join(", "));
        }
        None => println!("labels: (none — numeric classes)"),
    }

    println!("\nops ({}):", spec.layers().len());
    let names = spec.layer_names();
    for (i, (op, shape)) in spec
        .layers()
        .iter()
        .zip(spec.output_shapes())
        .enumerate()
    {
        let detail = match op {
            bitkernel::model::LayerSpec::Conv2d {
                cout, ksize, stride, pad, binarized,
            } => format!(
                "{cout}c {ksize}x{ksize} s{stride} p{pad}{}",
                if *binarized { " binarized" } else { "" }
            ),
            bitkernel::model::LayerSpec::Linear { dout, binarized } => {
                format!(
                    "{dout}d{}",
                    if *binarized { " binarized" } else { "" }
                )
            }
            _ => String::new(),
        };
        // (bound first: width specs pad strings, not arbitrary Display)
        let shape_s = shape.to_string();
        println!(
            "  {i:>3}  {:<10} {:<10} -> {:<12} {}",
            op.op_name(),
            names[i].as_deref().unwrap_or("-"),
            shape_s,
            detail
        );
    }

    let kernel = parse_kernel(args.get_or("kernel", "xnor"))?;
    let batch = args.get_usize("batch", 8)?;
    let engine = BnnEngine::from_weight_file(&wf)?;
    let plan = engine.plan(kernel, batch)?;
    println!(
        "\nplan ({} / max_batch {}): {} stages",
        kernel.name(),
        batch,
        plan.num_ops()
    );
    for name in plan.stage_names() {
        println!("  {name}");
    }
    let impls = plan.xnor_impls();
    if !impls.is_empty() {
        let labels: Vec<String> =
            impls.iter().map(|i| i.name().to_string()).collect();
        println!("resolved xnor impls: {}", labels.join(", "));
    }

    println!("\nsession buffers (per replica):");
    let mut total = 0usize;
    for (name, elems, bytes) in plan.buffer_sizes() {
        total += bytes;
        println!("  {name:<20} {elems:>10} elems  {:>10.1} KiB",
                 bytes as f64 / 1024.0);
    }
    println!("  {:<20} {:>10}        {:>10.1} KiB", "total", "",
             total as f64 / 1024.0);
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let specs = [COMMON[0].clone(), COMMON[1].clone()];
    let args = Args::parse(argv, &specs)?;
    if args.has("help") {
        print!("{}", render_help("inspect", "summarize artifacts", &specs));
        return Ok(());
    }
    let manifest =
        bitkernel::runtime::Manifest::load(args.get_or("artifacts", "artifacts"))
            .context("load manifest (run `make artifacts`)")?;
    println!("artifacts: {}", manifest.dir.display());
    println!("\nmodels ({}):", manifest.models.len());
    for m in &manifest.models {
        println!(
            "  {:<28} variant {:<10} scale {:<5} batch {:<3} args {}",
            m.name, m.variant, m.scale, m.batch,
            m.inputs.len()
        );
    }
    println!("\nkernels ({}):", manifest.kernels.len());
    for k in &manifest.kernels {
        println!(
            "  {:<24} {}x{}x{} ({})",
            k.name, k.d, k.k, k.n, k.kernel
        );
    }
    println!("\nweights:");
    for w in &manifest.weights {
        println!("  {:<8} {} (scale {}, trained: {})",
                 w.name, w.file, w.scale, w.trained);
    }
    Ok(())
}

fn cmd_selftest(argv: &[String]) -> Result<()> {
    let specs = [COMMON[0].clone(), COMMON[1].clone()];
    let args = Args::parse(argv, &specs)?;
    if args.has("help") {
        print!("{}", render_help("selftest", "verify kernel arms", &specs));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let ds = Dataset::load(dir.join("dataset_test.bin"))?;
    let engine = BnnEngine::load(dir.join("weights_small.bkw"))?;
    let x = ds.normalized(0, 4);
    let reference = engine.forward(&x, EngineKernel::Optimized);
    let mut ok = true;
    // Every single-threaded impl (derived, so new tiers can't be
    // silently skipped) plus the Auto plan-time dispatch.
    let mut arms = vec![EngineKernel::Control];
    arms.extend(XnorImpl::ALL_SINGLE.iter().map(|&i| EngineKernel::Xnor(i)));
    arms.push(EngineKernel::Xnor(XnorImpl::Auto));
    for kernel in arms {
        let diff = engine.forward(&x, kernel).max_abs_diff(&reference);
        let status = if diff <= 2e-3 { "ok" } else { "FAIL" };
        if diff > 2e-3 {
            ok = false;
        }
        println!("{:<16} max |Δlogit| = {diff:.2e}  {status}", kernel.name());
    }
    if !ok {
        bail!("selftest failed");
    }
    println!("all arms agree");
    Ok(())
}
