//! Minimal dense tensors: an NCHW-oriented f32 [`Tensor`] and the packed
//! 1-bit [`PackedMatrix`] used by the xnor-bitcount kernels.
//!
//! Deliberately small: row-major contiguous storage, shape checks in
//! debug, and just the views the BNN engine needs.  No strides/broadcast
//! machinery — layers reshape explicitly, mirroring the paper's im2col
//! data flow.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Wrap `data` with `shape` (element counts must match).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Self { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    /// The dimension sizes.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major element view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element view.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        self.shape = shape;
        self
    }

    /// Dimension helper with bounds message.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Re-dimension in place, reusing the backing buffer.  Never shrinks
    /// capacity; never reallocates when the new element count (and rank)
    /// fits the existing capacity — the primitive `model::plan::Session`
    /// uses to keep its output tensor allocation-free across runs.
    pub fn reset(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        self.data.resize(n, 0.0);
        self.shape.clear();
        self.shape.extend_from_slice(shape);
    }

    /// Capacity of the backing buffer (allocation diagnostics; see the
    /// steady-state checks in `tests/plan_session.rs`).
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Row view of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[r * w..(r + 1) * w]
    }

    /// Elementwise maximum absolute difference (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Bit-packed {-1,+1} matrix: `rows` logical rows of `k` elements, each
/// row packed little-endian into `kw = ceil(k/32)` u32 words
/// (bit 1 <=> value +1; padding bits are 0, i.e. value -1).
///
/// Both operands of the xnor gemm use this layout: the weight matrix
/// packs its rows directly; the activation matrix packs the *columns* of
/// the im2col output, i.e. the rows of its transpose — so reduction runs
/// contiguously for both sides.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedMatrix {
    /// Logical row count.
    pub rows: usize,
    /// Logical (unpadded) reduction length.
    pub k: usize,
    /// Words per row = ceil(k / 32).
    pub kw: usize,
    /// Row-major [rows, kw].
    pub data: Vec<u32>,
}

impl PackedMatrix {
    /// All-(-1) matrix (every packed bit 0) of the given logical shape.
    pub fn zeros(rows: usize, k: usize) -> Self {
        let kw = k.div_ceil(32);
        Self { rows, k, kw, data: vec![0; rows * kw] }
    }

    /// Empty matrix whose word buffer can hold `words` u32s without
    /// reallocating (pre-sizing for [`PackedMatrix::reset`]).
    pub fn with_word_capacity(words: usize) -> Self {
        Self { rows: 0, k: 0, kw: 0, data: Vec::with_capacity(words) }
    }

    /// Re-dimension in place, reusing the word buffer.  No reallocation
    /// when `rows * ceil(k/32)` fits the existing capacity — the packed
    /// scratch buffers of `model::plan::Session` cycle through every
    /// layer shape of a network this way.
    pub fn reset(&mut self, rows: usize, k: usize) {
        self.rows = rows;
        self.k = k;
        self.kw = k.div_ceil(32);
        self.data.resize(rows * self.kw, 0);
    }

    /// Capacity of the word buffer (allocation diagnostics).
    pub fn word_capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Packed words of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.kw..(r + 1) * self.kw]
    }

    /// Mutable packed words of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [u32] {
        &mut self.data[r * self.kw..(r + 1) * self.kw]
    }

    /// Number of zero-padding bits per row.
    #[inline]
    pub fn pad_bits(&self) -> i32 {
        (self.kw * 32 - self.k) as i32
    }

    /// Logical element (r, i) in the value domain {-1.0, +1.0}.
    pub fn get(&self, r: usize, i: usize) -> f32 {
        assert!(i < self.k);
        let w = self.data[r * self.kw + i / 32];
        if (w >> (i % 32)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.row(1), &[0.0; 3]);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_mismatched_shape() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshaped(vec![3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn packed_matrix_layout() {
        let mut p = PackedMatrix::zeros(2, 40);
        assert_eq!(p.kw, 2);
        assert_eq!(p.pad_bits(), 24);
        p.row_mut(1)[0] = 1; // bit 0 of row 1
        assert_eq!(p.get(1, 0), 1.0);
        assert_eq!(p.get(1, 1), -1.0);
        assert_eq!(p.get(0, 0), -1.0);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn tensor_reset_reuses_buffer() {
        let mut t = Tensor::zeros(vec![4, 10]);
        let ptr = t.data().as_ptr();
        let cap = t.capacity();
        t.reset(&[2, 10]);
        assert_eq!(t.shape(), &[2, 10]);
        assert_eq!(t.len(), 20);
        t.reset(&[4, 10]); // grow back within capacity
        assert_eq!(t.data().as_ptr(), ptr);
        assert_eq!(t.capacity(), cap);
    }

    #[test]
    fn packed_reset_reuses_buffer() {
        let mut p = PackedMatrix::with_word_capacity(8);
        let cap = p.word_capacity();
        p.reset(2, 40); // 2 rows * 2 words
        assert_eq!((p.rows, p.k, p.kw), (2, 40, 2));
        assert_eq!(p.data.len(), 4);
        p.reset(4, 64); // 4 rows * 2 words = 8 words, still in capacity
        assert_eq!(p.data.len(), 8);
        assert_eq!(p.word_capacity(), cap);
    }
}
