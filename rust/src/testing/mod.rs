//! Mini property-testing harness (offline substrate for proptest).
//!
//! [`prop_check`] runs a predicate over `iters` pseudo-random cases and,
//! on failure, retries with the same seed to report the failing case
//! index — enough for the shrinking-free invariant checks this repo
//! needs (bit-packing round trips, kernel equivalences, batcher
//! invariants).

/// Fault-injection harness for the serving pipeline (installable
/// `FaultPlan`: scheduled replica panics, inference delays, weight-read
/// faults) — see `rust/tests/chaos.rs`.
pub mod chaos;

use crate::utils::Rng;

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropFailure {
    /// Index of the failing case.
    pub case: usize,
    /// The harness seed (rerun with it to reproduce).
    pub seed: u64,
    /// The predicate's failure message.
    pub message: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed {}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Run `prop` over `iters` cases.  The closure receives a per-case RNG
/// (derived deterministically from `seed` and the case index) and
/// returns `Err(message)` to fail the property.
pub fn prop_check<F>(seed: u64, iters: usize, prop: F) -> Result<(), PropFailure>
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..iters {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(message) = prop(&mut rng, case) {
            return Err(PropFailure { case, seed, message });
        }
    }
    Ok(())
}

/// Assert-style wrapper: panics with the failing case on error.
pub fn prop_assert<F>(seed: u64, iters: usize, prop: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    if let Err(f) = prop_check(seed, iters, prop) {
        panic!("{f}");
    }
}

/// Random dimension helper in [1, hi].
pub fn dim(rng: &mut Rng, hi: usize) -> usize {
    1 + rng.below(hi)
}

/// Assemble an in-memory BKW2 [`crate::model::WeightFile`] (spec
/// embedded) for ANY validated [`crate::model::NetSpec`], with random
/// scheme-appropriate weights (sign-binarized ±1, or {-1, 0, +1} for
/// ternary-scheme specs), random (signed!) folded-BN affines, and —
/// for α-carrying schemes — a positive per-output-channel `.alpha`
/// tensor per binarized layer.  No artifacts on disk needed.
/// `tests/netspec.rs` writes these through the BKW2 serializer to pin
/// the round trip; `tests/scheme_conformance.rs` drives every scheme
/// through it.
pub fn synthetic_weight_file(spec: &crate::model::NetSpec, seed: u64)
                             -> crate::model::WeightFile {
    use crate::model::{Dtype, WeightFile, WeightTensor};
    use std::collections::BTreeMap;

    let f32t = |vals: Vec<f32>, shape: Vec<usize>| {
        WeightTensor::owned(
            Dtype::F32,
            shape,
            vals.iter().map(|v| v.to_bits()).collect(),
        )
    };
    let scheme = spec.scheme();
    let ternary = scheme.is_ternary();
    let wvals = move |rng: &mut Rng, n: usize| -> Vec<f32> {
        if ternary {
            (0..n).map(|_| rng.below(3) as f32 - 1.0).collect()
        } else {
            rng.sign_vec(n)
        }
    };
    // Strictly positive per-channel scales (the semantic analogue of
    // XNOR-Net's E|w|; exact value is irrelevant to bit-identity).
    let avals = |rng: &mut Rng, n: usize| -> Vec<f32> {
        rng.normal_vec(n).iter().map(|v| v.abs() + 0.5).collect()
    };
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    // The same derived-dim walk the engine loader uses — blocks()
    // resolves cin/din and the canonical names from the validated
    // shape trace, so the fixture generator cannot drift from it.
    let (convs, fcs) = spec.blocks();
    for s in &convs {
        tensors.insert(
            format!("{}.w", s.name),
            f32t(wvals(&mut rng, s.cout * s.k()),
                 vec![s.cout, s.cin, s.ksize, s.ksize]),
        );
        tensors.insert(format!("bn_{}.a", s.name),
                       f32t(rng.normal_vec(s.cout), vec![s.cout]));
        tensors.insert(format!("bn_{}.b", s.name),
                       f32t(rng.normal_vec(s.cout), vec![s.cout]));
        if s.binarized && scheme.has_alpha() {
            tensors.insert(format!("{}.alpha", s.name),
                           f32t(avals(&mut rng, s.cout), vec![s.cout]));
        }
    }
    for s in &fcs {
        tensors.insert(
            format!("{}.w", s.name),
            f32t(wvals(&mut rng, s.dout * s.din), vec![s.dout, s.din]),
        );
        tensors.insert(format!("bn_{}.a", s.name),
                       f32t(rng.normal_vec(s.dout), vec![s.dout]));
        tensors.insert(format!("bn_{}.b", s.name),
                       f32t(rng.normal_vec(s.dout), vec![s.dout]));
        if s.binarized && scheme.has_alpha() {
            tensors.insert(format!("{}.alpha", s.name),
                           f32t(avals(&mut rng, s.dout), vec![s.dout]));
        }
    }
    WeightFile::from_tensors_with_spec(tensors, spec.clone())
}

/// Build a [`crate::model::BnnEngine`] for ANY validated
/// [`crate::model::NetSpec`] from [`synthetic_weight_file`] tensors, so
/// tests and benches can exercise arbitrary topologies: odd input
/// shapes, any class count, fc-only nets, non-binarized layers
/// anywhere.
pub fn synthetic_engine_spec(spec: &crate::model::NetSpec, seed: u64)
                             -> crate::model::BnnEngine {
    crate::model::BnnEngine::from_weight_file(
        &synthetic_weight_file(spec, seed),
    )
    .expect("synthetic weight file")
}

/// [`synthetic_engine_spec`] over the legacy CIFAR topology: `widths`
/// follows the BKW1 `meta.widths` layout `[c1..c6, f1, f2, classes]`
/// (requiring `widths[4] == widths[5]`, the conv6 width == the fc1
/// flatten width).
///
/// This is the oracle substrate for `tests/plan_session.rs`: small
/// widths keep a full forward pass fast while exercising every layer
/// kind (float conv1, binarized convs, pooling, all three fcs).
pub fn synthetic_engine(widths: [u32; 9], seed: u64)
                        -> crate::model::BnnEngine {
    let spec = crate::model::NetSpec::from_widths(&widths)
        .expect("legacy widths");
    synthetic_engine_spec(&spec, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_assert(1, 50, |rng, _| {
            let x = rng.next_u32();
            if x as u64 <= u32::MAX as u64 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = prop_check(2, 100, |_, case| {
            if case == 17 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
        let f = r.unwrap_err();
        assert_eq!(f.case, 17);
        assert!(f.to_string().contains("boom"));
    }

    #[test]
    fn deterministic_rng_per_case() {
        // Same seed -> same per-case streams.
        use std::cell::RefCell;
        let a = RefCell::new(Vec::new());
        let b = RefCell::new(Vec::new());
        prop_check(4, 3, |rng, _| {
            a.borrow_mut().push(rng.next_u64());
            Ok(())
        })
        .unwrap();
        prop_check(4, 3, |rng, _| {
            b.borrow_mut().push(rng.next_u64());
            Ok(())
        })
        .unwrap();
        assert_eq!(a.into_inner(), b.into_inner());
    }
}
