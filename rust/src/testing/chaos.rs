//! Fault-injection harness (offline substrate for a chaos-mesh /
//! failpoint crate): a process-global, explicitly installed
//! [`FaultPlan`] that the serving pipeline consults at a few
//! well-chosen choke points.
//!
//! Hooks (no-ops — one relaxed atomic load — unless a plan is
//! installed):
//!
//! * [`before_infer`] — called by each replica worker just before
//!   `Backend::infer`; can delay the batch ([`FaultPlan::delay`]) or
//!   panic the replica ([`FaultPlan::panic_on`] /
//!   [`FaultPlan::arm_panic`]), which exercises the router's
//!   catch_unwind supervision and respawn path;
//! * [`weight_read_fault`] — consulted by the model registry before
//!   opening a weight file; [`FaultPlan::fail_weight_reads`] makes the
//!   next N opens fail, which exercises mount/respawn error paths.
//!
//! Installation is scoped: [`FaultPlan::install`] returns a
//! [`ChaosGuard`] that uninstalls on drop AND holds a process-wide
//! install lock, so concurrent `#[test]`s that each install a plan
//! serialize instead of contaminating each other.  The `serve` CLI
//! installs a plan for the process lifetime from the
//! `BITKERNEL_CHAOS` environment variable ([`FaultPlan::from_env`]) —
//! e.g. `BITKERNEL_CHAOS='panic=0@3;delay_ms=20;fail_reads=2'` — which
//! is how `examples/chaos_smoke.rs`-style drills run against a real
//! server binary.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock};
use std::time::Duration;

/// Fast path: is ANY plan installed?  Keeps the request-path cost of
/// an idle harness to one relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// The installed plan (present iff `ENABLED`).
static ACTIVE: RwLock<Option<Arc<FaultPlan>>> = RwLock::new(None);
/// Serializes installs across threads/tests; held by [`ChaosGuard`].
static INSTALL: Mutex<()> = Mutex::new(());

/// A set of faults to inject, built with the fluent methods or parsed
/// from `BITKERNEL_CHAOS` ([`FaultPlan::from_env`]), then activated
/// with [`FaultPlan::install`].
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// One-shot scheduled panics: replica `r` panics when it reaches
    /// batch sequence number >= `n` (1-based, per-replica).
    scheduled: Mutex<Vec<(usize, u64)>>,
    /// One-shot armed panics: replica `r` panics on its next batch.
    armed: Mutex<Vec<usize>>,
    /// Artificial delay before every `Backend::infer`.
    delay: Option<Duration>,
    /// Fail the next N weight-file opens seen by the registry.
    fail_reads: AtomicU64,
}

impl FaultPlan {
    /// An empty plan (injects nothing until faults are added).
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule a one-shot panic: replica `replica` panics when its
    /// per-replica batch counter reaches `batch` (1-based; `>=` so the
    /// fault cannot be skipped over).
    pub fn panic_on(self, replica: usize, batch: u64) -> Self {
        self.scheduled
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push((replica, batch));
        self
    }

    /// Delay every inference by `d` (keeps batches in flight long
    /// enough for tests to race deadlines and panics against them).
    pub fn delay(mut self, d: Duration) -> Self {
        self.delay = Some(d);
        self
    }

    /// Make the next `n` weight-file opens fail with an injected
    /// error (mount/lazy-build/respawn error paths).
    pub fn fail_weight_reads(self, n: u64) -> Self {
        self.fail_reads.store(n, Ordering::Relaxed);
        self
    }

    /// Arm a one-shot panic on `replica`'s NEXT batch — callable
    /// after install (e.g. from a bench driver thread injecting a
    /// panic every second).
    pub fn arm_panic(&self, replica: usize) {
        self.armed
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(replica);
    }

    /// Parse a plan from the `BITKERNEL_CHAOS` grammar:
    /// `;`-separated directives, each `panic=<replica>@<batch>`,
    /// `delay_ms=<n>`, or `fail_reads=<n>` (repeatable `panic=`).
    pub fn from_env(spec: &str) -> anyhow::Result<Self> {
        let mut plan = Self::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("chaos directive '{part}' is not key=value")
            })?;
            match key {
                "panic" => {
                    let (r, b) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!(
                            "chaos panic '{val}' is not <replica>@<batch>"
                        )
                    })?;
                    plan = plan.panic_on(r.parse()?, b.parse()?);
                }
                "delay_ms" => {
                    plan = plan
                        .delay(Duration::from_millis(val.parse()?));
                }
                "fail_reads" => {
                    plan = plan.fail_weight_reads(val.parse()?);
                }
                _ => anyhow::bail!("unknown chaos directive '{key}'"),
            }
        }
        Ok(plan)
    }

    /// Install this plan process-wide, returning a guard that
    /// uninstalls it on drop.  Blocks while another plan is installed
    /// (tests running in parallel serialize here instead of injecting
    /// faults into each other's routers).
    pub fn install(self) -> ChaosGuard {
        let lock = INSTALL
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let plan = Arc::new(self);
        *ACTIVE.write().unwrap_or_else(PoisonError::into_inner) =
            Some(Arc::clone(&plan));
        ENABLED.store(true, Ordering::SeqCst);
        ChaosGuard { plan, _lock: lock }
    }

    /// Execute the infer-side faults for (`replica`, `batch_seq`).
    fn fire_before_infer(&self, replica: usize, batch_seq: u64) {
        if let Some(d) = self.delay {
            std::thread::sleep(d);
        }
        let armed = {
            let mut armed = self
                .armed
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            match armed.iter().position(|&r| r == replica) {
                Some(i) => {
                    armed.swap_remove(i);
                    true
                }
                None => false,
            }
        };
        let scheduled = {
            let mut sched = self
                .scheduled
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            match sched
                .iter()
                .position(|&(r, b)| r == replica && batch_seq >= b)
            {
                Some(i) => {
                    sched.swap_remove(i);
                    true
                }
                None => false,
            }
        };
        if armed || scheduled {
            panic!(
                "chaos: injected panic on replica {replica} \
                 batch {batch_seq}"
            );
        }
    }
}

/// Scope of an installed [`FaultPlan`]: uninstalls on drop and holds
/// the process-wide install lock for its lifetime.
pub struct ChaosGuard {
    plan: Arc<FaultPlan>,
    _lock: MutexGuard<'static, ()>,
}

impl ChaosGuard {
    /// The installed plan — e.g. to [`FaultPlan::arm_panic`] more
    /// faults while the plan is live.
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        *ACTIVE.write().unwrap_or_else(PoisonError::into_inner) = None;
    }
}

/// The currently installed plan, if any.
fn active() -> Option<Arc<FaultPlan>> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    ACTIVE
        .read()
        .unwrap_or_else(PoisonError::into_inner)
        .clone()
}

/// Replica-worker hook, called just before `Backend::infer` with the
/// replica id and that replica's 1-based batch sequence number.  May
/// sleep (injected delay) or panic (injected replica fault); a no-op
/// unless a [`FaultPlan`] is installed.
pub fn before_infer(replica: usize, batch_seq: u64) {
    if let Some(plan) = active() {
        plan.fire_before_infer(replica, batch_seq);
    }
}

/// Registry hook, consulted before opening a weight file.  Returns
/// `true` when the open should fail (consuming one injected fault);
/// always `false` with no plan installed.
pub fn weight_read_fault() -> bool {
    match active() {
        Some(plan) => plan
            .fail_reads
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                n.checked_sub(1)
            })
            .is_ok(),
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hooks_are_noops_without_a_plan() {
        // Hold the install lock so no parallel test's plan is active,
        // then check the hooks are inert: no panic, no weight faults.
        let _lock =
            INSTALL.lock().unwrap_or_else(PoisonError::into_inner);
        before_infer(0, 1);
        assert!(!weight_read_fault());
    }

    #[test]
    fn env_grammar_round_trips() {
        let plan = FaultPlan::from_env(
            "panic=1@3; delay_ms=5;fail_reads=2;panic=0@9",
        )
        .unwrap();
        assert_eq!(plan.delay, Some(Duration::from_millis(5)));
        assert_eq!(plan.fail_reads.load(Ordering::Relaxed), 2);
        assert_eq!(
            *plan.scheduled.lock().unwrap(),
            vec![(1, 3), (0, 9)]
        );
        assert!(FaultPlan::from_env("panic=oops").is_err());
        assert!(FaultPlan::from_env("warp=9").is_err());
        assert!(FaultPlan::from_env("").unwrap().delay.is_none());
    }

    #[test]
    fn install_scopes_faults_and_guard_uninstalls() {
        let guard = FaultPlan::new().fail_weight_reads(2).install();
        assert!(weight_read_fault());
        assert!(weight_read_fault());
        assert!(!weight_read_fault(), "budget exhausted");
        drop(guard);
        assert!(!weight_read_fault(), "uninstalled");
    }

    #[test]
    fn scheduled_and_armed_panics_fire_once() {
        let guard = FaultPlan::new().panic_on(1, 2).install();
        before_infer(0, 2); // other replica: no fault
        before_infer(1, 1); // before the scheduled batch
        let caught = std::panic::catch_unwind(|| before_infer(1, 5));
        assert!(caught.is_err(), ">= semantics: late batch still fires");
        before_infer(1, 6); // one-shot: consumed
        guard.plan().arm_panic(0);
        let caught = std::panic::catch_unwind(|| before_infer(0, 7));
        assert!(caught.is_err());
        before_infer(0, 8); // armed fault consumed
    }
}
