//! Neural-network building blocks for the native BNN engine.
//!
//! The data flow mirrors the paper's Figure 2/3 exactly:
//!
//! ```text
//!     x (NCHW) -> im2col -> [encode] -> gemm/xnor-gemm -> col2im -> BN
//! ```
//!
//! with the single twist that the im2col matrix is stored TRANSPOSED
//! ([N, K] row-major, one output position's patch per row) so that both
//! the bit-packing and every gemm kernel reduce over contiguous memory.

pub mod conv;
pub mod fuse;
pub mod im2col;
pub mod linear;
pub mod norm;
pub mod ops;
pub mod pool;

pub use conv::{conv2d, ConvKernel};
pub use fuse::{alpha_col2im_nchw, alpha_col2im_nchw_i32,
               bn_rows_from_gemm_f32, bn_rows_from_gemm_f32_alpha,
               bn_rows_from_gemm_i32, bn_rows_from_gemm_i32_alpha,
               bn_sign_pack_nchw, bn_sign_pack_rows_f32,
               bn_sign_pack_rows_f32_alpha, bn_sign_pack_rows_i32,
               bn_sign_pack_rows_i32_alpha};
pub use im2col::{col2im_nchw, im2col_t, out_hw};
pub use linear::linear;
pub use norm::{bn_affine_nchw, bn_affine_rows};
pub use ops::{argmax, htanh, sign_inplace, softmax_inplace};
pub use pool::maxpool2;
