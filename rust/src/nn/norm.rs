//! Folded inference BatchNorm: per-channel affine `y = a*x + b`.

use crate::tensor::Tensor;

/// Apply a per-channel affine over an NCHW tensor, in place.
pub fn bn_affine_nchw(x: &mut Tensor, a: &[f32], b: &[f32]) {
    let (batch, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    bn_affine_nchw_slice(x.data_mut(), batch, c, h * w, a, b);
}

/// Core of [`bn_affine_nchw`] over a raw `[batch, c, hw]` slice (the
/// plan executor's buffer-based entry point).
pub fn bn_affine_nchw_slice(data: &mut [f32], batch: usize, c: usize,
                            hw: usize, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), c);
    assert_eq!(b.len(), c);
    assert_eq!(data.len(), batch * c * hw, "activation len");
    for bi in 0..batch {
        for ci in 0..c {
            let (ac, bc) = (a[ci], b[ci]);
            for v in &mut data[(bi * c + ci) * hw..][..hw] {
                *v = ac * *v + bc;
            }
        }
    }
}

/// Apply a per-feature affine over a [B, F] matrix, in place.
pub fn bn_affine_rows(x: &mut Tensor, a: &[f32], b: &[f32]) {
    let (batch, f) = (x.dim(0), x.dim(1));
    assert_eq!(a.len(), f);
    assert_eq!(b.len(), f);
    let data = x.data_mut();
    for bi in 0..batch {
        for (fi, v) in data[bi * f..(bi + 1) * f].iter_mut().enumerate() {
            *v = a[fi] * *v + b[fi];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nchw_per_channel() {
        let mut x = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        bn_affine_nchw(&mut x, &[2.0, -1.0], &[0.5, 0.0]);
        assert_eq!(x.data(), &[2.5, 4.5, -3.0, -4.0]);
    }

    #[test]
    fn nchw_batch_dim() {
        let mut x = Tensor::new(vec![2, 1, 1, 1], vec![1.0, 10.0]);
        bn_affine_nchw(&mut x, &[3.0], &[1.0]);
        assert_eq!(x.data(), &[4.0, 31.0]);
    }

    #[test]
    fn rows_per_feature() {
        let mut x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        bn_affine_rows(&mut x, &[1.0, 10.0], &[0.0, -1.0]);
        assert_eq!(x.data(), &[1.0, 19.0, 3.0, 39.0]);
    }

    #[test]
    #[should_panic]
    fn wrong_channel_count_panics() {
        let mut x = Tensor::zeros(vec![1, 3, 1, 1]);
        bn_affine_nchw(&mut x, &[1.0], &[0.0]);
    }
}
