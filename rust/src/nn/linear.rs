//! Fully-connected layer over the same kernel family as the convs.
//!
//! Input [B, K] (flattened activations), weights [D, K]; output [B, D].
//! The binarized arms sign the activations first (and the xnor arm packs
//! them), exactly like the FC layers in python/compile/model.py.

use crate::bitops::{pack_rows, xnor_gemm, XnorImpl};
use crate::gemm::{gemm_f32, GemmImpl};
use crate::tensor::{PackedMatrix, Tensor};

use super::conv::ConvWeights;
use super::ops::sign_inplace;

/// Kernel choice for a linear layer (same arms as conv).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearKernel {
    /// Sign+pack the activations, xnor-bitcount gemm.
    Xnor(XnorImpl),
    /// Sign the activations, float gemm on {-1,+1}.
    FloatBinarized(GemmImpl),
    /// No binarization at all: plain float gemm on the raw activations
    /// (a NetSpec `Linear { binarized: false }` — e.g. the real-input
    /// first layer of an fc-only net — runs this on every arm).
    FloatReal(GemmImpl),
}

/// x: [B, K] -> [B, D].
pub fn linear(
    x: &Tensor,
    weights: &ConvWeights,
    d: usize,
    kernel: LinearKernel,
) -> Tensor {
    let (b, k) = (x.dim(0), x.dim(1));
    match (kernel, weights) {
        (LinearKernel::Xnor(imp), ConvWeights::Packed(wp)) => {
            assert_eq!(wp.rows, d);
            assert_eq!(wp.k, k);
            let xp: PackedMatrix = pack_rows(x.data(), b, k);
            // out_gemm[d, b] -> transpose into [b, d]
            let mut gemm_out = vec![0i32; d * b];
            xnor_gemm(wp, &xp, &mut gemm_out, imp);
            let mut out = vec![0.0f32; b * d];
            for di in 0..d {
                for bi in 0..b {
                    out[bi * d + di] = gemm_out[di * b + bi] as f32;
                }
            }
            Tensor::new(vec![b, d], out)
        }
        (LinearKernel::FloatBinarized(imp), ConvWeights::Float(wf)) => {
            assert_eq!(wf.len(), d * k);
            let mut xb = x.clone();
            sign_inplace(xb.data_mut());
            let mut gemm_out = vec![0.0f32; d * b];
            gemm_f32(wf, xb.data(), &mut gemm_out, d, k, b, imp);
            let mut out = vec![0.0f32; b * d];
            for di in 0..d {
                for bi in 0..b {
                    out[bi * d + di] = gemm_out[di * b + bi];
                }
            }
            Tensor::new(vec![b, d], out)
        }
        (LinearKernel::FloatReal(imp), ConvWeights::Float(wf)) => {
            assert_eq!(wf.len(), d * k);
            let mut gemm_out = vec![0.0f32; d * b];
            gemm_f32(wf, x.data(), &mut gemm_out, d, k, b, imp);
            let mut out = vec![0.0f32; b * d];
            for di in 0..d {
                for bi in 0..b {
                    out[bi * d + di] = gemm_out[di * b + bi];
                }
            }
            Tensor::new(vec![b, d], out)
        }
        (kern, _) => panic!("weight form does not match kernel {kern:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    #[test]
    fn arms_agree_and_match_dense() {
        let (b, k, d) = (3, 70, 5);
        let mut rng = Rng::new(2);
        let xf = rng.normal_vec(b * k);
        let wf = rng.sign_vec(d * k);
        let x = Tensor::new(vec![b, k], xf.clone());

        // dense reference on signs
        let mut want = vec![0.0f32; b * d];
        for bi in 0..b {
            for di in 0..d {
                want[bi * d + di] = (0..k)
                    .map(|kk| {
                        let xv = if xf[bi * k + kk] >= 0.0 { 1.0 } else { -1.0 };
                        xv * wf[di * k + kk]
                    })
                    .sum();
            }
        }

        let got_f = linear(
            &x,
            &ConvWeights::float(wf.clone()),
            d,
            LinearKernel::FloatBinarized(GemmImpl::Naive),
        );
        assert_eq!(got_f.data(), &want[..]);

        let wp = pack_rows(&wf, d, k);
        let got_x = linear(
            &x,
            &ConvWeights::packed(wp),
            d,
            LinearKernel::Xnor(XnorImpl::Blocked),
        );
        assert_eq!(got_x.data(), &want[..]);
    }

    #[test]
    fn float_real_skips_binarization() {
        let (b, k, d) = (2, 9, 3);
        let mut rng = Rng::new(3);
        let xf = rng.normal_vec(b * k);
        let wf = rng.normal_vec(d * k);
        let x = Tensor::new(vec![b, k], xf.clone());
        let got = linear(
            &x,
            &ConvWeights::float(wf.clone()),
            d,
            LinearKernel::FloatReal(GemmImpl::Naive),
        );
        for bi in 0..b {
            for di in 0..d {
                let want: f32 = (0..k)
                    .map(|kk| xf[bi * k + kk] * wf[di * k + kk])
                    .sum();
                assert!((got.data()[bi * d + di] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn output_shape() {
        let x = Tensor::zeros(vec![2, 8]);
        let w = ConvWeights::float(vec![1.0; 3 * 8]);
        let y = linear(&x, &w, 3, LinearKernel::FloatBinarized(GemmImpl::Blocked));
        assert_eq!(y.shape(), &[2, 3]);
        // all-zero input binarizes to +1; +1 dot +1 over k=8 = 8
        assert!(y.data().iter().all(|&v| v == 8.0));
    }
}
