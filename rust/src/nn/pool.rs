//! 2x2 max pooling (stride 2), the BNN's only pooling op.

use crate::tensor::Tensor;

/// NCHW [B, C, H, W] -> [B, C, H/2, W/2].  H and W must be even.
pub fn maxpool2(x: &Tensor) -> Tensor {
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let mut out = vec![0.0f32; b * c * (h / 2) * (w / 2)];
    maxpool2_into(x.data(), b * c, h, w, &mut out);
    Tensor::new(vec![b, c, h / 2, w / 2], out)
}

/// Core of [`maxpool2`] over `planes = B*C` contiguous HxW planes,
/// writing a caller-owned buffer (`out.len() == planes * (h/2) * (w/2)`).
pub fn maxpool2_into(xd: &[f32], planes: usize, h: usize, w: usize,
                     out: &mut [f32]) {
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even dims, got {h}x{w}");
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(xd.len(), planes * h * w, "input len");
    assert_eq!(out.len(), planes * oh * ow, "output len");
    for p in 0..planes {
        let src = &xd[p * h * w..][..h * w];
        let dst = &mut out[p * oh * ow..][..oh * ow];
        for oy in 0..oh {
            let r0 = &src[2 * oy * w..][..w];
            let r1 = &src[(2 * oy + 1) * w..][..w];
            for ox in 0..ow {
                let m = r0[2 * ox]
                    .max(r0[2 * ox + 1])
                    .max(r1[2 * ox])
                    .max(r1[2 * ox + 1]);
                dst[oy * ow + ox] = m;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_maxima() {
        let x = Tensor::new(
            vec![1, 1, 4, 4],
            (0..16).map(|i| i as f32).collect(),
        );
        let y = maxpool2(&x);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn channels_independent() {
        let mut data = vec![0.0; 2 * 2 * 2];
        data[0..4].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]); // channel 0
        data[4..8].copy_from_slice(&[-1.0, -2.0, -3.0, -4.0]); // channel 1
        let x = Tensor::new(vec![1, 2, 2, 2], data);
        let y = maxpool2(&x);
        assert_eq!(y.data(), &[4.0, -1.0]);
    }

    #[test]
    fn negative_values() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![-5.0, -3.0, -8.0, -4.0]);
        assert_eq!(maxpool2(&x).data(), &[-3.0]);
    }

    #[test]
    #[should_panic(expected = "even dims")]
    fn odd_dims_panic() {
        maxpool2(&Tensor::zeros(vec![1, 1, 3, 4]));
    }
}
