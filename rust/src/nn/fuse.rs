//! Fused layer-boundary epilogues for the plan/session execution path.
//!
//! The paper's speedup comes from keeping the xnor-bitcount inner loop
//! tight; these kernels keep the glue between layers tight too.  On the
//! xnor arm a binarized fc layer's output is consumed only as SIGNS by
//! the next layer, so the unfused chain
//!
//! ```text
//!     gemm i32 [D,B] -> transpose+f32 [B,D] -> bn affine -> sign -> pack
//! ```
//!
//! (three full passes plus two materialized float matrices) collapses
//! into ONE pass that emits the next layer's [`PackedMatrix`] directly —
//! the `bn_sign_pack` epilogue op of `model::plan`.  All variants are
//! bit-identical to the unfused pipeline: they perform the same f32
//! multiply-add in the same order and only skip the materialization
//! (pinned by the tests below and by `tests/plan_session.rs`).

use crate::bitops::pack::BitWriter;
use crate::tensor::PackedMatrix;

/// Xnor fc epilogue: gemm output [D, B] (i32, row-major) + per-feature
/// affine `y = a*x + b` -> packed sign rows [B, D] for the next
/// binarized layer.  `out` must be pre-`reset` to (B, D); every word
/// (including the zero padding bits) is overwritten.
pub fn bn_sign_pack_rows_i32(gemm: &[i32], d: usize, b: usize,
                             a: &[f32], bias: &[f32],
                             out: &mut PackedMatrix) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(a.len(), d, "bn scale len");
    assert_eq!(bias.len(), d, "bn shift len");
    assert_eq!(out.rows, b, "packed rows");
    assert_eq!(out.k, d, "packed k");
    let kw = out.kw;
    for bi in 0..b {
        let mut bw =
            BitWriter::new(&mut out.data[bi * kw..(bi + 1) * kw]);
        for di in 0..d {
            let v = a[di] * gemm[di * b + bi] as f32 + bias[di];
            bw.push(u32::from(v >= 0.0));
        }
        bw.finish();
    }
}

/// [`bn_sign_pack_rows_i32`] for f32 gemm output — the epilogue of a
/// NON-binarized (real-input, float-gemm) fc layer whose consumer is
/// binarized, e.g. fc1 of an fc-only net on the xnor arm.
pub fn bn_sign_pack_rows_f32(gemm: &[f32], d: usize, b: usize,
                             a: &[f32], bias: &[f32],
                             out: &mut PackedMatrix) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(a.len(), d, "bn scale len");
    assert_eq!(bias.len(), d, "bn shift len");
    assert_eq!(out.rows, b, "packed rows");
    assert_eq!(out.k, d, "packed k");
    let kw = out.kw;
    for bi in 0..b {
        let mut bw =
            BitWriter::new(&mut out.data[bi * kw..(bi + 1) * kw]);
        for di in 0..d {
            let v = a[di] * gemm[di * b + bi] + bias[di];
            bw.push(u32::from(v >= 0.0));
        }
        bw.finish();
    }
}

/// Xnor flatten epilogue: float NCHW activation (post-pool, PRE-bn) +
/// per-channel affine -> packed sign rows [B, C*HW].  Row-major NCHW
/// flattening is exactly the (c, h, w) feature order of fc1, so this
/// replaces `bn_affine_nchw` + flatten + `pack_rows` with one pass.
pub fn bn_sign_pack_nchw(x: &[f32], b: usize, c: usize, hw: usize,
                         a: &[f32], bias: &[f32], out: &mut PackedMatrix) {
    assert_eq!(x.len(), b * c * hw, "activation len");
    assert_eq!(a.len(), c, "bn scale len");
    assert_eq!(bias.len(), c, "bn shift len");
    assert_eq!(out.rows, b, "packed rows");
    assert_eq!(out.k, c * hw, "packed k");
    let kw = out.kw;
    for bi in 0..b {
        let src = &x[bi * c * hw..(bi + 1) * c * hw];
        let mut bw =
            BitWriter::new(&mut out.data[bi * kw..(bi + 1) * kw]);
        for ci in 0..c {
            // Whole-channel sign run: SIMD-packed once word-aligned.
            bw.push_signs_bn(&src[ci * hw..(ci + 1) * hw], a[ci],
                             bias[ci]);
        }
        bw.finish();
    }
}

/// Fused transpose + bn for i32 gemm output: [D, B] -> float rows
/// [B, D] with `y = a*x + b` applied per feature (the final-logits
/// epilogue of the xnor arm).
pub fn bn_rows_from_gemm_i32(gemm: &[i32], d: usize, b: usize,
                             a: &[f32], bias: &[f32], out: &mut [f32]) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(out.len(), b * d, "output len");
    assert_eq!(a.len(), d);
    assert_eq!(bias.len(), d);
    for di in 0..d {
        let (ac, bc) = (a[di], bias[di]);
        for bi in 0..b {
            out[bi * d + di] = ac * gemm[di * b + bi] as f32 + bc;
        }
    }
}

/// [`bn_rows_from_gemm_i32`] for float gemm output (the fc epilogue of
/// the Control/Optimized arms).
pub fn bn_rows_from_gemm_f32(gemm: &[f32], d: usize, b: usize,
                             a: &[f32], bias: &[f32], out: &mut [f32]) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(out.len(), b * d, "output len");
    assert_eq!(a.len(), d);
    assert_eq!(bias.len(), d);
    for di in 0..d {
        let (ac, bc) = (a[di], bias[di]);
        for bi in 0..b {
            out[bi * d + di] = ac * gemm[di * b + bi] + bc;
        }
    }
}

/// [`bn_sign_pack_rows_i32`] with the XNOR-Net per-output-channel α
/// multiplied in AFTER the popcount, BEFORE the bn affine:
/// `y = a * (alpha * g) + b`.  The reference path scales the gemm
/// output then applies bn — the same two f32 ops in the same order —
/// so fused and unfused stay bit-identical.
pub fn bn_sign_pack_rows_i32_alpha(gemm: &[i32], d: usize, b: usize,
                                   alpha: &[f32], a: &[f32],
                                   bias: &[f32], out: &mut PackedMatrix) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(alpha.len(), d, "alpha len");
    assert_eq!(a.len(), d, "bn scale len");
    assert_eq!(bias.len(), d, "bn shift len");
    assert_eq!(out.rows, b, "packed rows");
    assert_eq!(out.k, d, "packed k");
    let kw = out.kw;
    for bi in 0..b {
        let mut bw =
            BitWriter::new(&mut out.data[bi * kw..(bi + 1) * kw]);
        for di in 0..d {
            let v = a[di] * (alpha[di] * gemm[di * b + bi] as f32)
                + bias[di];
            bw.push(u32::from(v >= 0.0));
        }
        bw.finish();
    }
}

/// [`bn_sign_pack_rows_i32_alpha`] for f32 gemm output.
pub fn bn_sign_pack_rows_f32_alpha(gemm: &[f32], d: usize, b: usize,
                                   alpha: &[f32], a: &[f32],
                                   bias: &[f32], out: &mut PackedMatrix) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(alpha.len(), d, "alpha len");
    assert_eq!(a.len(), d, "bn scale len");
    assert_eq!(bias.len(), d, "bn shift len");
    assert_eq!(out.rows, b, "packed rows");
    assert_eq!(out.k, d, "packed k");
    let kw = out.kw;
    for bi in 0..b {
        let mut bw =
            BitWriter::new(&mut out.data[bi * kw..(bi + 1) * kw]);
        for di in 0..d {
            let v = a[di] * (alpha[di] * gemm[di * b + bi]) + bias[di];
            bw.push(u32::from(v >= 0.0));
        }
        bw.finish();
    }
}

/// [`bn_rows_from_gemm_i32`] with the α scale folded in:
/// `y = a * (alpha * g) + b` (the final-logits epilogue of an
/// α-scaled fc layer).
pub fn bn_rows_from_gemm_i32_alpha(gemm: &[i32], d: usize, b: usize,
                                   alpha: &[f32], a: &[f32],
                                   bias: &[f32], out: &mut [f32]) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(out.len(), b * d, "output len");
    assert_eq!(alpha.len(), d);
    assert_eq!(a.len(), d);
    assert_eq!(bias.len(), d);
    for di in 0..d {
        let (sc, ac, bc) = (alpha[di], a[di], bias[di]);
        for bi in 0..b {
            out[bi * d + di] = ac * (sc * gemm[di * b + bi] as f32) + bc;
        }
    }
}

/// [`bn_rows_from_gemm_i32_alpha`] for float gemm output.
pub fn bn_rows_from_gemm_f32_alpha(gemm: &[f32], d: usize, b: usize,
                                   alpha: &[f32], a: &[f32],
                                   bias: &[f32], out: &mut [f32]) {
    assert_eq!(gemm.len(), d * b, "gemm len");
    assert_eq!(out.len(), b * d, "output len");
    assert_eq!(alpha.len(), d);
    assert_eq!(a.len(), d);
    assert_eq!(bias.len(), d);
    for di in 0..d {
        let (sc, ac, bc) = (alpha[di], a[di], bias[di]);
        for bi in 0..b {
            out[bi * d + di] = ac * (sc * gemm[di * b + bi]) + bc;
        }
    }
}

/// col2im fused with the i32 -> f32 conversion AND the per-output-
/// channel α scale (`y = alpha[d] * g`; multiply only — an `+ 0.0`
/// affine would turn `-0.0` into `+0.0` and break bit-identity with
/// the reference's plain scale).  Layout mirrors
/// [`crate::nn::im2col::col2im_nchw_i32_into`].
pub fn alpha_col2im_nchw_i32(gemm: &[i32], b: usize, d: usize,
                             oh: usize, ow: usize, alpha: &[f32],
                             out: &mut [f32]) {
    let n = b * oh * ow;
    assert_eq!(gemm.len(), d * n, "gemm len");
    assert_eq!(out.len(), d * n, "output len");
    assert_eq!(alpha.len(), d, "alpha len");
    let hw = oh * ow;
    for di in 0..d {
        let sc = alpha[di];
        let src = &gemm[di * n..(di + 1) * n];
        for bi in 0..b {
            let dst = &mut out[(bi * d + di) * hw..][..hw];
            for (o, &v) in dst.iter_mut().zip(&src[bi * hw..(bi + 1) * hw])
            {
                *o = sc * v as f32;
            }
        }
    }
}

/// [`alpha_col2im_nchw_i32`] for float gemm output (the α conv
/// epilogue of the Control/Optimized arms).
pub fn alpha_col2im_nchw(gemm: &[f32], b: usize, d: usize, oh: usize,
                         ow: usize, alpha: &[f32], out: &mut [f32]) {
    let n = b * oh * ow;
    assert_eq!(gemm.len(), d * n, "gemm len");
    assert_eq!(out.len(), d * n, "output len");
    assert_eq!(alpha.len(), d, "alpha len");
    let hw = oh * ow;
    for di in 0..d {
        let sc = alpha[di];
        let src = &gemm[di * n..(di + 1) * n];
        for bi in 0..b {
            let dst = &mut out[(bi * d + di) * hw..][..hw];
            for (o, &v) in dst.iter_mut().zip(&src[bi * hw..(bi + 1) * hw])
            {
                *o = sc * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack_rows;
    use crate::nn::norm::{bn_affine_nchw, bn_affine_rows};
    use crate::tensor::Tensor;
    use crate::utils::Rng;

    /// Unfused oracle for the fc epilogue: transpose to [B, D] float,
    /// bn affine, pack rows — exactly the legacy engine's data flow.
    fn unfused_rows_i32(gemm: &[i32], d: usize, b: usize, a: &[f32],
                        bias: &[f32]) -> (Vec<f32>, PackedMatrix) {
        let mut rows = vec![0.0f32; b * d];
        for di in 0..d {
            for bi in 0..b {
                rows[bi * d + di] = gemm[di * b + bi] as f32;
            }
        }
        let mut t = Tensor::new(vec![b, d], rows);
        bn_affine_rows(&mut t, a, bias);
        let packed = pack_rows(t.data(), b, d);
        (t.into_data(), packed)
    }

    #[test]
    fn bn_sign_pack_rows_matches_unfused() {
        let mut rng = Rng::new(40);
        for (d, b) in [(10, 1), (33, 3), (64, 8), (70, 5)] {
            let gemm: Vec<i32> =
                (0..d * b).map(|_| rng.below(41) as i32 - 20).collect();
            let a = rng.normal_vec(d); // signed scales on purpose
            let bias = rng.normal_vec(d);
            let (_, want) = unfused_rows_i32(&gemm, d, b, &a, &bias);
            let mut got = PackedMatrix::zeros(b, d);
            // poison: stale bits must be fully overwritten
            got.data.fill(0xDEAD_BEEF);
            bn_sign_pack_rows_i32(&gemm, d, b, &a, &bias, &mut got);
            assert_eq!(got, want, "d={d} b={b}");
        }
    }

    #[test]
    fn bn_rows_from_gemm_matches_unfused() {
        let mut rng = Rng::new(41);
        let (d, b) = (10, 4);
        let gemm: Vec<i32> =
            (0..d * b).map(|_| rng.below(21) as i32 - 10).collect();
        let a = rng.normal_vec(d);
        let bias = rng.normal_vec(d);
        let (want, _) = unfused_rows_i32(&gemm, d, b, &a, &bias);
        let mut got = vec![0.0f32; b * d];
        bn_rows_from_gemm_i32(&gemm, d, b, &a, &bias, &mut got);
        assert_eq!(got, want);

        // f32 variant agrees on integer-valued inputs
        let gemm_f: Vec<f32> = gemm.iter().map(|&v| v as f32).collect();
        let mut got_f = vec![0.0f32; b * d];
        bn_rows_from_gemm_f32(&gemm_f, d, b, &a, &bias, &mut got_f);
        assert_eq!(got_f, want);
    }

    #[test]
    fn bn_sign_pack_rows_f32_matches_i32_twin() {
        let mut rng = Rng::new(43);
        for (d, b) in [(10, 1), (33, 3), (70, 5)] {
            let gemm: Vec<i32> =
                (0..d * b).map(|_| rng.below(41) as i32 - 20).collect();
            let gemm_f: Vec<f32> = gemm.iter().map(|&v| v as f32).collect();
            let a = rng.normal_vec(d);
            let bias = rng.normal_vec(d);
            let mut want = PackedMatrix::zeros(b, d);
            bn_sign_pack_rows_i32(&gemm, d, b, &a, &bias, &mut want);
            let mut got = PackedMatrix::zeros(b, d);
            got.data.fill(0xDEAD_BEEF);
            bn_sign_pack_rows_f32(&gemm_f, d, b, &a, &bias, &mut got);
            assert_eq!(got, want, "d={d} b={b}");
        }
    }

    #[test]
    fn alpha_epilogues_match_unfused_scale_then_bn() {
        let mut rng = Rng::new(44);
        for (d, b) in [(10, 1), (33, 3), (64, 8), (70, 5)] {
            let gemm: Vec<i32> =
                (0..d * b).map(|_| rng.below(41) as i32 - 20).collect();
            let alpha: Vec<f32> =
                rng.normal_vec(d).iter().map(|v| v.abs()).collect();
            let a = rng.normal_vec(d);
            let bias = rng.normal_vec(d);
            // unfused oracle: transpose + scale, then bn, then pack —
            // the forward_reference data flow for an α-scaled layer.
            let mut rows = vec![0.0f32; b * d];
            for di in 0..d {
                for bi in 0..b {
                    rows[bi * d + di] =
                        alpha[di] * gemm[di * b + bi] as f32;
                }
            }
            let mut t = Tensor::new(vec![b, d], rows);
            bn_affine_rows(&mut t, &a, &bias);
            let want_rows = t.data().to_vec();
            let want_packed = pack_rows(t.data(), b, d);

            let mut got = PackedMatrix::zeros(b, d);
            got.data.fill(0xDEAD_BEEF);
            bn_sign_pack_rows_i32_alpha(&gemm, d, b, &alpha, &a, &bias,
                                        &mut got);
            assert_eq!(got, want_packed, "i32 pack d={d} b={b}");

            let gemm_f: Vec<f32> =
                gemm.iter().map(|&v| v as f32).collect();
            got.data.fill(0xDEAD_BEEF);
            bn_sign_pack_rows_f32_alpha(&gemm_f, d, b, &alpha, &a, &bias,
                                        &mut got);
            assert_eq!(got, want_packed, "f32 pack d={d} b={b}");

            let mut got_rows = vec![7.5f32; b * d];
            bn_rows_from_gemm_i32_alpha(&gemm, d, b, &alpha, &a, &bias,
                                        &mut got_rows);
            assert_eq!(got_rows, want_rows, "i32 rows d={d} b={b}");
            got_rows.fill(7.5);
            bn_rows_from_gemm_f32_alpha(&gemm_f, d, b, &alpha, &a, &bias,
                                        &mut got_rows);
            assert_eq!(got_rows, want_rows, "f32 rows d={d} b={b}");
        }
    }

    #[test]
    fn alpha_col2im_matches_scale_after_col2im() {
        use crate::nn::im2col::col2im_nchw_i32;
        let mut rng = Rng::new(45);
        for (b, d, oh, ow) in [(1, 3, 2, 2), (2, 5, 3, 4), (3, 1, 1, 7)] {
            let n = b * oh * ow;
            let gemm: Vec<i32> =
                (0..d * n).map(|_| rng.below(61) as i32 - 30).collect();
            let alpha: Vec<f32> =
                rng.normal_vec(d).iter().map(|v| v.abs()).collect();
            // oracle: plain col2im, then per-channel multiply
            let t = col2im_nchw_i32(&gemm, b, d, oh, ow);
            let mut want = t.data().to_vec();
            let hw = oh * ow;
            for bi in 0..b {
                for di in 0..d {
                    for v in &mut want[(bi * d + di) * hw..][..hw] {
                        *v *= alpha[di];
                    }
                }
            }
            let mut got = vec![9.0f32; d * n];
            alpha_col2im_nchw_i32(&gemm, b, d, oh, ow, &alpha, &mut got);
            assert_eq!(got, want, "i32 b={b} d={d}");

            let gemm_f: Vec<f32> =
                gemm.iter().map(|&v| v as f32).collect();
            got.fill(9.0);
            alpha_col2im_nchw(&gemm_f, b, d, oh, ow, &alpha, &mut got);
            assert_eq!(got, want, "f32 b={b} d={d}");
        }
    }

    #[test]
    fn bn_sign_pack_nchw_matches_unfused() {
        let mut rng = Rng::new(42);
        for (b, c, hw) in [(1, 3, 16), (2, 8, 16), (3, 5, 9)] {
            let x = Tensor::new(vec![b, c, hw, 1],
                                rng.normal_vec(b * c * hw));
            let a = rng.normal_vec(c);
            let bias = rng.normal_vec(c);
            // oracle: bn on NCHW, flatten (row-major no-op), pack rows
            let mut xb = x.clone();
            bn_affine_nchw(&mut xb, &a, &bias);
            let want = pack_rows(xb.data(), b, c * hw);
            let mut got = PackedMatrix::zeros(b, c * hw);
            got.data.fill(0xFFFF_FFFF);
            bn_sign_pack_nchw(x.data(), b, c, hw, &a, &bias, &mut got);
            assert_eq!(got, want, "b={b} c={c} hw={hw}");
        }
    }
}
