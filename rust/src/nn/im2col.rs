//! im2col / col2im — the paper's Figure 1.
//!
//! `im2col_t` lowers an NCHW batch into the TRANSPOSED column matrix
//! [N, K] (N = B*OH*OW output positions ordered (b, oh, ow); K = C*kh*kw
//! patch elements ordered (c, i, j), matching
//! `lax.conv_general_dilated_patches` and python's ref.im2col_ref).
//! Spatial zero padding inserts literal 0.0 values — binarization maps
//! them to +1 downstream, identical to the python oracle.
//!
//! Every transform exists in two forms: an allocating convenience
//! (`im2col_t`, `col2im_nchw`, ...) and an `_into` core that writes a
//! caller-owned buffer — the plan/session execution path uses only the
//! latter so `Session::run` stays allocation-free in steady state.

use crate::bitops::pack::BitWriter;
use crate::tensor::{PackedMatrix, Tensor};

/// Output spatial dims for a conv.
pub fn out_hw(h: usize, w: usize, kh: usize, kw: usize, stride: usize,
              pad: usize) -> (usize, usize) {
    ((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1)
}

/// NCHW [B, C, H, W] -> transposed column matrix [B*OH*OW, C*kh*kw].
pub fn im2col_t(x: &Tensor, kh: usize, kw: usize, stride: usize,
                pad: usize) -> Tensor {
    let (b, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (oh, ow) = out_hw(h, w, kh, kw, stride, pad);
    let k = c * kh * kw;
    let n = b * oh * ow;
    let mut out = vec![0.0f32; n * k];
    im2col_t_into(x.data(), b, c, h, w, kh, kw, stride, pad, &mut out);
    Tensor::new(vec![n, k], out)
}

/// Core of [`im2col_t`] over raw slices, writing a caller-owned buffer
/// (`out.len() == B*OH*OW * C*kh*kw`; fully overwritten).
#[allow(clippy::too_many_arguments)]
pub fn im2col_t_into(xd: &[f32], b: usize, c: usize, h: usize, w: usize,
                     kh: usize, kw: usize, stride: usize, pad: usize,
                     out: &mut [f32]) {
    let (oh, ow) = out_hw(h, w, kh, kw, stride, pad);
    let k = c * kh * kw;
    let n = b * oh * ow;
    assert_eq!(xd.len(), b * c * h * w, "input len");
    assert_eq!(out.len(), n * k, "column buffer len");
    out.fill(0.0); // padding positions stay zero

    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut out[((bi * oh + oy) * ow + ox) * k..][..k];
                let iy0 = (oy * stride) as isize - pad as isize;
                let ix0 = (ox * stride) as isize - pad as isize;
                let mut idx = 0;
                for ci in 0..c {
                    let plane = &xd[(bi * c + ci) * h * w..][..h * w];
                    for dy in 0..kh {
                        let iy = iy0 + dy as isize;
                        if iy < 0 || iy >= h as isize {
                            idx += kw; // row stays zero (padding)
                            continue;
                        }
                        let src = &plane[iy as usize * w..][..w];
                        for dx in 0..kw {
                            let ix = ix0 + dx as isize;
                            if ix >= 0 && ix < w as isize {
                                row[idx] = src[ix as usize];
                            }
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
}

/// Fused im2col + encode (§Perf optimization 1): pack the binarized
/// column matrix straight from the NCHW input, never materializing the
/// [N, K] float matrix.  Exactly equivalent to
/// `pack_rows(im2col_t(x, ..).data(), n, k)`:
/// spatial padding contributes value 0.0 -> sign +1 -> bit 1.
pub fn im2col_pack(x: &Tensor, kh: usize, kw: usize, stride: usize,
                   pad: usize, out: &mut PackedMatrix) {
    im2col_pack_bn(x.data(), x.dim(0), x.dim(1), x.dim(2), x.dim(3),
                   kh, kw, stride, pad, None, out);
}

/// [`im2col_pack`] over raw slices, optionally folding the PREVIOUS
/// layer's per-channel BatchNorm affine into the sign: when `bn` is
/// `Some((a, b))` each interior element contributes bit
/// `a[c]*v + b[c] >= 0` — bit-identical to materializing
/// `bn_affine_nchw` and packing the result (same f32 ops, same order) —
/// while im2col's own zero padding stays bit 1 (it is inserted AFTER the
/// affine in the unfused pipeline).  This is the xnor arm's layer-fusion
/// path: binarized conv layers never materialize a bn'd float
/// activation.
#[allow(clippy::too_many_arguments)]
pub fn im2col_pack_bn(xd: &[f32], b: usize, c: usize, h: usize, w: usize,
                      kh: usize, kw: usize, stride: usize, pad: usize,
                      bn: Option<(&[f32], &[f32])>,
                      out: &mut PackedMatrix) {
    let (oh, ow) = out_hw(h, w, kh, kw, stride, pad);
    let k = c * kh * kw;
    let n = b * oh * ow;
    assert_eq!(xd.len(), b * c * h * w, "input len");
    assert_eq!(out.rows, n, "packed rows");
    assert_eq!(out.k, k, "packed k");
    if let Some((a, bb)) = bn {
        assert_eq!(a.len(), c, "bn scale len");
        assert_eq!(bb.len(), c, "bn shift len");
    }
    let kwords = out.kw;

    for bi in 0..b {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad as isize;
            for ox in 0..ow {
                let r = (bi * oh + oy) * ow + ox;
                let row = &mut out.data[r * kwords..(r + 1) * kwords];
                let ix0 = (ox * stride) as isize - pad as isize;
                let mut bw = BitWriter::new(row);
                for ci in 0..c {
                    let plane = &xd[(bi * c + ci) * h * w..][..h * w];
                    let (ac, bc) = match bn {
                        Some((a, bb)) => (a[ci], bb[ci]),
                        None => (1.0, 0.0),
                    };
                    for dy in 0..kh {
                        let iy = iy0 + dy as isize;
                        if iy < 0 || iy >= h as isize {
                            // padding: value 0.0 -> sign +1 -> bit 1
                            for _ in 0..kw {
                                bw.push(1);
                            }
                            continue;
                        }
                        let src = &plane[iy as usize * w..][..w];
                        let in_x0 = ix0.max(0) as usize;
                        let in_x1 = (ix0 + kw as isize).min(w as isize)
                            as usize;
                        // left pad
                        for _ in 0..(in_x0 as isize - ix0) {
                            bw.push(1);
                        }
                        // interior: sign-run push (SIMD whole words once
                        // word-aligned); the bn=None path keeps the
                        // plain compare (no identity affine cost on the
                        // legacy encode loop)
                        let interior = &src[in_x0..in_x1.max(in_x0)];
                        if bn.is_some() {
                            bw.push_signs_bn(interior, ac, bc);
                        } else {
                            bw.push_signs(interior);
                        }
                        // right pad
                        for _ in 0..(ix0 + kw as isize
                            - in_x1.max(in_x0) as isize)
                        {
                            bw.push(1);
                        }
                    }
                }
                bw.finish();
            }
        }
    }
}

/// Gemm output [D, N] (row-major) -> NCHW [B, D, OH, OW].
pub fn col2im_nchw(gemm_out: &[f32], b: usize, d: usize, oh: usize,
                   ow: usize) -> Tensor {
    let mut out = vec![0.0f32; d * b * oh * ow];
    col2im_nchw_into(gemm_out, b, d, oh, ow, &mut out);
    Tensor::new(vec![b, d, oh, ow], out)
}

/// Core of [`col2im_nchw`] writing a caller-owned buffer.
pub fn col2im_nchw_into(gemm_out: &[f32], b: usize, d: usize, oh: usize,
                        ow: usize, out: &mut [f32]) {
    let n = b * oh * ow;
    assert_eq!(gemm_out.len(), d * n);
    assert_eq!(out.len(), d * n);
    let hw = oh * ow;
    for di in 0..d {
        let src = &gemm_out[di * n..(di + 1) * n];
        for bi in 0..b {
            out[(bi * d + di) * hw..][..hw]
                .copy_from_slice(&src[bi * hw..(bi + 1) * hw]);
        }
    }
}

/// col2im fused with the i32 -> f32 conversion of the xnor gemm output
/// (§Perf optimization 3: one pass instead of convert-then-copy).
pub fn col2im_nchw_i32(gemm_out: &[i32], b: usize, d: usize, oh: usize,
                       ow: usize) -> Tensor {
    let mut out = vec![0.0f32; d * b * oh * ow];
    col2im_nchw_i32_into(gemm_out, b, d, oh, ow, &mut out);
    Tensor::new(vec![b, d, oh, ow], out)
}

/// Core of [`col2im_nchw_i32`] writing a caller-owned buffer.
pub fn col2im_nchw_i32_into(gemm_out: &[i32], b: usize, d: usize,
                            oh: usize, ow: usize, out: &mut [f32]) {
    let n = b * oh * ow;
    assert_eq!(gemm_out.len(), d * n);
    assert_eq!(out.len(), d * n);
    let hw = oh * ow;
    for di in 0..d {
        let src = &gemm_out[di * n..(di + 1) * n];
        for bi in 0..b {
            let dst = &mut out[(bi * d + di) * hw..][..hw];
            for (o, &v) in dst.iter_mut().zip(&src[bi * hw..(bi + 1) * hw]) {
                *o = v as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|i| i as f32).collect())
    }

    #[test]
    fn shapes() {
        let x = seq_tensor(vec![2, 3, 8, 10]);
        let cols = im2col_t(&x, 3, 3, 1, 1);
        assert_eq!(cols.shape(), &[2 * 8 * 10, 27]);
        assert_eq!(out_hw(8, 10, 3, 3, 1, 1), (8, 10));
        assert_eq!(out_hw(8, 10, 3, 3, 2, 1), (4, 5));
    }

    #[test]
    fn identity_1x1() {
        // 1x1 kernel, no pad: row n is exactly the channel vector at that
        // position.
        let x = seq_tensor(vec![1, 2, 2, 2]);
        let cols = im2col_t(&x, 1, 1, 1, 0);
        assert_eq!(cols.shape(), &[4, 2]);
        // position (0,0): channels [0, 4]; position (1,1): [3, 7]
        assert_eq!(cols.row(0), &[0.0, 4.0]);
        assert_eq!(cols.row(3), &[3.0, 7.0]);
    }

    #[test]
    fn padding_zeros() {
        let x = Tensor::full(vec![1, 1, 2, 2], 5.0);
        let cols = im2col_t(&x, 3, 3, 1, 1);
        assert_eq!(cols.shape(), &[4, 9]);
        // top-left output: the 3x3 patch centered at (0,0) has 5 pad zeros
        let row = cols.row(0);
        assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 5);
        assert_eq!(row.iter().filter(|&&v| v == 5.0).count(), 4);
    }

    #[test]
    fn patch_element_order_is_c_i_j() {
        // One channel distinct from the other: K index = c*kh*kw + i*kw + j.
        let mut data = vec![0.0f32; 2 * 3 * 3];
        for (i, v) in data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let x = Tensor::new(vec![1, 2, 3, 3], data);
        let cols = im2col_t(&x, 3, 3, 1, 0);
        assert_eq!(cols.shape(), &[1, 18]);
        // Single output position: row = [c0 row-major .. c1 row-major].
        let want: Vec<f32> = (0..18).map(|i| i as f32).collect();
        assert_eq!(cols.row(0), &want[..]);
    }

    #[test]
    fn stride_2() {
        let x = seq_tensor(vec![1, 1, 4, 4]);
        let cols = im2col_t(&x, 2, 2, 2, 0);
        assert_eq!(cols.shape(), &[4, 4]);
        assert_eq!(cols.row(0), &[0.0, 1.0, 4.0, 5.0]);
        assert_eq!(cols.row(3), &[10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn col2im_roundtrip_layout() {
        // D=2 channels, B=2, OH=OW=1: gemm layout [D, N] with N=(b)
        let gemm_out = [1.0, 2.0, 10.0, 20.0]; // d0: [b0, b1], d1: [b0, b1]
        let t = col2im_nchw(&gemm_out, 2, 2, 1, 1);
        assert_eq!(t.shape(), &[2, 2, 1, 1]);
        assert_eq!(t.data(), &[1.0, 10.0, 2.0, 20.0]);
    }

    #[test]
    fn into_variants_overwrite_stale_data() {
        // Reused buffers must not leak previous contents (padding zeros
        // and every interior element are rewritten).
        let x = seq_tensor(vec![1, 1, 3, 3]);
        let want = im2col_t(&x, 3, 3, 1, 1);
        let n = want.dim(0);
        let k = want.dim(1);
        let mut buf = vec![7.5f32; n * k];
        im2col_t_into(x.data(), 1, 1, 3, 3, 3, 3, 1, 1, &mut buf);
        assert_eq!(&buf[..], want.data());

        let gemm: Vec<i32> = (0..8).map(|i| i - 4).collect();
        let want = col2im_nchw_i32(&gemm, 2, 2, 1, 2);
        let mut out = vec![9.0f32; 8];
        col2im_nchw_i32_into(&gemm, 2, 2, 1, 2, &mut out);
        assert_eq!(&out[..], want.data());
    }
}

#[cfg(test)]
mod fused_tests {
    use super::*;
    use crate::bitops::pack_rows;
    use crate::tensor::PackedMatrix;
    use crate::utils::Rng;

    #[test]
    fn im2col_pack_equals_unfused() {
        let mut rng = Rng::new(21);
        for (b, c, h, w, ks, stride, pad) in [
            (1, 2, 6, 6, 3, 1, 1),
            (2, 3, 8, 8, 3, 1, 1),
            (1, 1, 5, 7, 3, 2, 1),
            (1, 4, 4, 4, 1, 1, 0),
            (2, 2, 9, 9, 5, 1, 2),
        ] {
            let x = Tensor::new(vec![b, c, h, w],
                                rng.normal_vec(b * c * h * w));
            let cols = im2col_t(&x, ks, ks, stride, pad);
            let n = cols.dim(0);
            let k = cols.dim(1);
            let want = pack_rows(cols.data(), n, k);
            let mut got = PackedMatrix::zeros(n, k);
            im2col_pack(&x, ks, ks, stride, pad, &mut got);
            assert_eq!(got, want, "b{b} c{c} {h}x{w} k{ks} s{stride} p{pad}");
        }
    }

    #[test]
    fn im2col_pack_padding_is_plus_one() {
        // all-negative input: real elements bit 0, padding bits 1.
        let x = Tensor::full(vec![1, 1, 2, 2], -5.0);
        let mut got = PackedMatrix::zeros(4, 9);
        im2col_pack(&x, 3, 3, 1, 1, &mut got);
        // top-left position: 5 padded (bit 1) + 4 real (bit 0)
        assert_eq!(got.row(0)[0].count_ones(), 5);
    }

    #[test]
    fn im2col_pack_bn_equals_materialized_bn() {
        use crate::nn::norm::bn_affine_nchw;
        let mut rng = Rng::new(33);
        for (b, c, h, w, ks, stride, pad) in [
            (2, 3, 6, 6, 3, 1, 1),
            (1, 4, 5, 5, 3, 2, 1),
            (1, 2, 4, 4, 1, 1, 0),
        ] {
            let x = Tensor::new(vec![b, c, h, w],
                                rng.normal_vec(b * c * h * w));
            // Signed scales on purpose: folding must respect a < 0.
            let a = rng.normal_vec(c);
            let bb = rng.normal_vec(c);

            // unfused oracle: materialize bn, then pack
            let mut xb = x.clone();
            bn_affine_nchw(&mut xb, &a, &bb);
            let cols = im2col_t(&xb, ks, ks, stride, pad);
            let want = pack_rows(cols.data(), cols.dim(0), cols.dim(1));

            let mut got = PackedMatrix::zeros(cols.dim(0), cols.dim(1));
            im2col_pack_bn(x.data(), b, c, h, w, ks, ks, stride, pad,
                           Some((&a[..], &bb[..])), &mut got);
            assert_eq!(got, want, "b{b} c{c} {h}x{w} k{ks}");
        }
    }
}
