//! Convolution via im2col + pluggable gemm — Figures 2 and 3 as code.
//!
//! [`ConvKernel`] selects the Table-2 arm:
//! * `Xnor(imp)`       — Figure 3: binarize+pack the column matrix, run
//!   the xnor-bitcount gemm (weights arrive pre-packed, Sec. 3.1),
//! * `FloatBinarized`  — Figure 2 on the SAME binarized network: sign the
//!   column matrix, float gemm on {-1,+1} (naive = Control Group,
//!   blocked = "optimized library" stand-in),
//! * `FloatReal`       — plain float conv (used for conv1, whose input
//!   stays real-valued in every arm).

use std::sync::Arc;

use crate::bitops::{pack_rows, xnor_gemm, XnorImpl};
use crate::gemm::{gemm_f32, GemmImpl};
use crate::tensor::{PackedMatrix, Tensor};

use super::im2col::{col2im_nchw, col2im_nchw_i32, im2col_t, out_hw};
use super::ops::sign_inplace;

/// The weights of one conv layer, in whichever form the kernel needs.
///
/// Weight storage is `Arc`-shared so a compiled execution plan
/// (`model::plan::Plan`) can hold the same buffers as the engine that
/// produced it: cloning a `ConvWeights` is a refcount bump, never a
/// copy of the matrix.
#[derive(Debug, Clone)]
pub enum ConvWeights {
    /// Row-major [D, K] float (K = C*kh*kw); values {-1,+1} for
    /// binarized layers.
    Float(Arc<Vec<f32>>),
    /// Pre-packed [D, K] bits (the paper's offline weight encoding).
    Packed(Arc<PackedMatrix>),
}

impl ConvWeights {
    /// Wrap a float weight matrix (takes ownership, Arc-shares it).
    pub fn float(v: Vec<f32>) -> Self {
        Self::Float(Arc::new(v))
    }

    /// Wrap a pre-packed weight matrix (takes ownership, Arc-shares it).
    pub fn packed(p: PackedMatrix) -> Self {
        Self::Packed(Arc::new(p))
    }
}

/// Which gemm runs inside the conv.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvKernel {
    /// Encode + xnor-bitcount (requires `ConvWeights::Packed`).
    Xnor(XnorImpl),
    /// Binarize activations, float gemm (requires `ConvWeights::Float`).
    FloatBinarized(GemmImpl),
    /// No binarization at all (conv1; requires `ConvWeights::Float`).
    FloatReal(GemmImpl),
}

/// Convolution parameters (square kernels, as in the BNN).
#[derive(Debug, Clone, Copy)]
pub struct ConvParams {
    /// Output channels.
    pub cout: usize,
    /// Input channels.
    pub cin: usize,
    /// Square kernel side.
    pub ksize: usize,
    /// Stride (both dims).
    pub stride: usize,
    /// Zero padding (both dims).
    pub pad: usize,
}

impl ConvParams {
    /// Gemm reduction length K = Cin * k * k.
    pub fn k(&self) -> usize {
        self.cin * self.ksize * self.ksize
    }
}

/// Scratch buffers reused across calls on the per-request hot path.
#[derive(Debug, Default)]
pub struct ConvScratch {
    /// Packed im2col column bits (xnor arm).
    pub cols_packed: Option<PackedMatrix>,
    /// i32 gemm output scratch (xnor arm).
    pub gemm_i32: Vec<i32>,
    /// f32 gemm output scratch (float arms).
    pub gemm_f32: Vec<f32>,
}

/// im2col convolution with the selected kernel.
///
/// `x`: [B, Cin, H, W]; returns [B, Cout, OH, OW].
pub fn conv2d(
    x: &Tensor,
    weights: &ConvWeights,
    p: &ConvParams,
    kernel: ConvKernel,
    scratch: &mut ConvScratch,
) -> Tensor {
    let (b, h, w) = (x.dim(0), x.dim(2), x.dim(3));
    assert_eq!(x.dim(1), p.cin, "input channels");
    let (oh, ow) = out_hw(h, w, p.ksize, p.ksize, p.stride, p.pad);
    let n = b * oh * ow;
    let k = p.k();
    let d = p.cout;

    match (kernel, weights) {
        (ConvKernel::Xnor(imp), ConvWeights::Packed(wp)) => {
            assert_eq!(wp.rows, d);
            assert_eq!(wp.k, k);
            // Fused im2col + encode (§Perf): pack the binarized column
            // matrix straight from the input; sign(0) = +1 on padding.
            let mut xp = match scratch.cols_packed.take() {
                Some(pm) if pm.rows == n && pm.k == k => pm,
                _ => PackedMatrix::zeros(n, k),
            };
            super::im2col::im2col_pack(x, p.ksize, p.ksize, p.stride,
                                       p.pad, &mut xp);
            scratch.gemm_i32.resize(d * n, 0);
            xnor_gemm(wp.as_ref(), &xp, &mut scratch.gemm_i32, imp);
            scratch.cols_packed = Some(xp);
            col2im_nchw_i32(&scratch.gemm_i32, b, d, oh, ow)
        }
        (ConvKernel::FloatBinarized(imp), ConvWeights::Float(wf)) => {
            assert_eq!(wf.len(), d * k);
            let mut cols = im2col_t(x, p.ksize, p.ksize, p.stride, p.pad);
            sign_inplace(cols.data_mut());
            scratch.gemm_f32.resize(d * n, 0.0);
            gemm_f32(wf, cols.data(), &mut scratch.gemm_f32, d, k, n, imp);
            col2im_nchw(&scratch.gemm_f32, b, d, oh, ow)
        }
        (ConvKernel::FloatReal(imp), ConvWeights::Float(wf)) => {
            assert_eq!(wf.len(), d * k);
            let cols = im2col_t(x, p.ksize, p.ksize, p.stride, p.pad);
            scratch.gemm_f32.resize(d * n, 0.0);
            gemm_f32(wf, cols.data(), &mut scratch.gemm_f32, d, k, n, imp);
            col2im_nchw(&scratch.gemm_f32, b, d, oh, ow)
        }
        (kern, _) => panic!("weight form does not match kernel {kern:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    /// Direct (quadruple-loop) conv reference on binarized operands with
    /// +1 padding in the sign domain — mirrors python ref.binconv2d_ref.
    fn binconv_reference(
        x: &Tensor,
        wf: &[f32],
        p: &ConvParams,
    ) -> Tensor {
        let (b, h, w) = (x.dim(0), x.dim(2), x.dim(3));
        let (oh, ow) = out_hw(h, w, p.ksize, p.ksize, p.stride, p.pad);
        let mut out = Tensor::zeros(vec![b, p.cout, oh, ow]);
        let sgn = |v: f32| if v >= 0.0 { 1.0 } else { -1.0 };
        for bi in 0..b {
            for di in 0..p.cout {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0;
                        for ci in 0..p.cin {
                            for dy in 0..p.ksize {
                                for dx in 0..p.ksize {
                                    let iy = (oy * p.stride + dy) as isize
                                        - p.pad as isize;
                                    let ix = (ox * p.stride + dx) as isize
                                        - p.pad as isize;
                                    let xv = if iy >= 0
                                        && iy < h as isize
                                        && ix >= 0
                                        && ix < w as isize
                                    {
                                        x.data()[((bi * p.cin + ci) * h
                                            + iy as usize)
                                            * w
                                            + ix as usize]
                                    } else {
                                        0.0 // sign(0) = +1 below
                                    };
                                    let wv = wf[di * p.k()
                                        + (ci * p.ksize + dy) * p.ksize
                                        + dx];
                                    acc += sgn(xv) * sgn(wv);
                                }
                            }
                        }
                        out.data_mut()
                            [((bi * p.cout + di) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
        out
    }

    fn case(b: usize, p: ConvParams, hw: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::new(
            vec![b, p.cin, hw, hw],
            rng.normal_vec(b * p.cin * hw * hw),
        );
        let wf_raw = rng.normal_vec(p.cout * p.k());
        let wf: Vec<f32> = wf_raw
            .iter()
            .map(|&v| if v >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        let want = binconv_reference(&x, &wf, &p);

        let mut scratch = ConvScratch::default();
        // Arm 1: xnor
        let wp = pack_rows(&wf, p.cout, p.k());
        let got_x = conv2d(
            &x,
            &ConvWeights::packed(wp),
            &p,
            ConvKernel::Xnor(XnorImpl::Blocked),
            &mut scratch,
        );
        assert_eq!(got_x.max_abs_diff(&want), 0.0, "xnor arm");
        // Arm 2: control (naive float)
        let got_c = conv2d(
            &x,
            &ConvWeights::float(wf.clone()),
            &p,
            ConvKernel::FloatBinarized(GemmImpl::Naive),
            &mut scratch,
        );
        assert_eq!(got_c.max_abs_diff(&want), 0.0, "control arm");
        // Arm 3: optimized (blocked float)
        let got_o = conv2d(
            &x,
            &ConvWeights::float(wf),
            &p,
            ConvKernel::FloatBinarized(GemmImpl::Blocked),
            &mut scratch,
        );
        assert_eq!(got_o.max_abs_diff(&want), 0.0, "optimized arm");
    }

    #[test]
    fn three_arms_match_direct_reference() {
        case(
            2,
            ConvParams { cout: 4, cin: 3, ksize: 3, stride: 1, pad: 1 },
            8,
            1,
        );
        case(
            1,
            ConvParams { cout: 5, cin: 2, ksize: 3, stride: 2, pad: 1 },
            9,
            2,
        );
        case(
            1,
            ConvParams { cout: 3, cin: 4, ksize: 1, stride: 1, pad: 0 },
            5,
            3,
        );
        case(
            2,
            ConvParams { cout: 2, cin: 1, ksize: 5, stride: 1, pad: 2 },
            7,
            4,
        );
    }

    #[test]
    fn float_real_matches_dense_math() {
        // FloatReal: no binarization; compare against direct float conv.
        let p = ConvParams { cout: 2, cin: 2, ksize: 3, stride: 1, pad: 0 };
        let mut rng = Rng::new(9);
        let x = Tensor::new(vec![1, 2, 5, 5], rng.normal_vec(50));
        let wf = rng.normal_vec(p.cout * p.k());
        let mut scratch = ConvScratch::default();
        let got = conv2d(
            &x,
            &ConvWeights::float(wf.clone()),
            &p,
            ConvKernel::FloatReal(GemmImpl::Blocked),
            &mut scratch,
        );
        // brute force
        let (oh, ow) = out_hw(5, 5, 3, 3, 1, 0);
        for di in 0..2 {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..2 {
                        for dy in 0..3 {
                            for dx in 0..3 {
                                acc += x.data()
                                    [(ci * 5 + oy + dy) * 5 + ox + dx]
                                    * wf[di * 18 + (ci * 3 + dy) * 3 + dx];
                            }
                        }
                    }
                    let got_v =
                        got.data()[(di * oh + oy) * ow + ox];
                    assert!((got_v - acc).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_is_safe() {
        let p = ConvParams { cout: 3, cin: 2, ksize: 3, stride: 1, pad: 1 };
        let mut rng = Rng::new(5);
        let wf: Vec<f32> = rng.sign_vec(p.cout * p.k());
        let wp = ConvWeights::packed(pack_rows(&wf, p.cout, p.k()));
        let mut scratch = ConvScratch::default();
        let x1 = Tensor::new(vec![1, 2, 6, 6], rng.normal_vec(72));
        let a1 = conv2d(&x1, &wp, &p,
                        ConvKernel::Xnor(XnorImpl::Scalar), &mut scratch);
        let a2 = conv2d(&x1, &wp, &p,
                        ConvKernel::Xnor(XnorImpl::Scalar), &mut scratch);
        assert_eq!(a1.max_abs_diff(&a2), 0.0);
    }

    #[test]
    #[should_panic(expected = "weight form")]
    fn mismatched_weight_form_panics() {
        let p = ConvParams { cout: 1, cin: 1, ksize: 1, stride: 1, pad: 0 };
        let x = Tensor::zeros(vec![1, 1, 2, 2]);
        conv2d(
            &x,
            &ConvWeights::float(vec![1.0]),
            &p,
            ConvKernel::Xnor(XnorImpl::Scalar),
            &mut ConvScratch::default(),
        );
    }
}
