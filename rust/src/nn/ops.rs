//! Elementwise ops: sign, htanh, softmax, argmax.

/// In-place deterministic binarization: sign(x) with sign(0) = +1
/// (matches the bit encoding and the python oracle).
pub fn sign_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = if *v >= 0.0 { 1.0 } else { -1.0 };
    }
}

/// Hard tanh: clip(x, -1, 1) — the BNN's training activation.  At
/// inference it only matters if applied before a non-sign consumer;
/// provided for completeness and the engine's optional activation taps.
pub fn htanh(x: f32) -> f32 {
    x.clamp(-1.0, 1.0)
}

/// Numerically-stable in-place softmax over a logits row.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_zero_is_plus_one() {
        let mut v = [-2.0, -0.0, 0.0, 3.0];
        sign_inplace(&mut v);
        assert_eq!(v, [-1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn htanh_clips() {
        assert_eq!(htanh(-3.0), -1.0);
        assert_eq!(htanh(0.25), 0.25);
        assert_eq!(htanh(9.0), 1.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut row = [1.0, 2.0, 3.0];
        softmax_inplace(&mut row);
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let mut row = [1000.0, 1001.0];
        softmax_inplace(&mut row);
        assert!(row.iter().all(|v| v.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
