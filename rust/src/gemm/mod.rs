//! Float-32 gemm kernels — the non-binarized arms of Table 2.
//!
//! Both take `a` as [D, k] row-major and `bt` as [N, k] row-major (the
//! TRANSPOSE of the mathematical right operand, matching the packed
//! layout used by the xnor kernels so every arm sees the same memory
//! traffic pattern) and write `out[i * n + j] = <a_i, bt_j>`.
//!
//! * [`gemm_naive`]   — the paper's Control Group (Sec 4.3): plain
//!   dot-product loops, no vendor library, no blocking.
//! * [`gemm_blocked`] — cache/register-blocked float gemm.
//! * [`gemm_simd`]    — the widened kernel standing in for the "highly
//!   optimized by MKL" PyTorch CPU row: AVX2 8-lane multiply-add with
//!   4-column register blocking when the CPU has it, else a portable
//!   8-wide unrolled fallback — so the Table-2 float baseline is as
//!   vectorized as the xnor kernel it is compared against.

/// Control-group gemm: naive dot products, one MAC per element.
pub fn gemm_naive(a: &[f32], bt: &[f32], out: &mut [f32], d: usize, k: usize, n: usize) {
    assert_eq!(a.len(), d * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), d * n);
    for i in 0..d {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bt[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Blocked gemm: 4-column register blocking + 4-way unrolled reduction
/// with independent accumulators (keeps the FMA pipeline busy), standing
/// in for the vendor-optimized float kernel.
pub fn gemm_blocked(a: &[f32], bt: &[f32], out: &mut [f32], d: usize, k: usize, n: usize) {
    assert_eq!(a.len(), d * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), d * n);
    let n4 = n & !3;
    for i in 0..d {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &bt[j * k..(j + 1) * k];
            let b1 = &bt[(j + 1) * k..(j + 2) * k];
            let b2 = &bt[(j + 2) * k..(j + 3) * k];
            let b3 = &bt[(j + 3) * k..(j + 4) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
            for kk in 0..k {
                let av = arow[kk];
                a0 += av * b0[kk];
                a1 += av * b1[kk];
                a2 += av * b2[kk];
                a3 += av * b3[kk];
            }
            orow[j] = a0;
            orow[j + 1] = a1;
            orow[j + 2] = a2;
            orow[j + 3] = a3;
            j += 4;
        }
        while j < n {
            let brow = &bt[j * k..(j + 1) * k];
            orow[j] = dot_unrolled(arow, brow);
            j += 1;
        }
    }
}

/// 4-way unrolled dot product with independent accumulators.
#[inline]
fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let k4 = a.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let mut kk = 0;
    while kk < k4 {
        s0 += a[kk] * b[kk];
        s1 += a[kk + 1] * b[kk + 1];
        s2 += a[kk + 2] * b[kk + 2];
        s3 += a[kk + 3] * b[kk + 3];
        kk += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while kk < a.len() {
        s += a[kk] * b[kk];
        kk += 1;
    }
    s
}

/// 8-wide unrolled dot product with independent accumulators (portable
/// tier of [`gemm_simd`]).
#[inline]
fn dot_wide(a: &[f32], b: &[f32]) -> f32 {
    let k8 = a.len() & !7;
    let mut s = [0.0f32; 8];
    let mut kk = 0;
    while kk < k8 {
        for (l, sl) in s.iter_mut().enumerate() {
            *sl += a[kk + l] * b[kk + l];
        }
        kk += 8;
    }
    let mut acc =
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    while kk < a.len() {
        acc += a[kk] * b[kk];
        kk += 1;
    }
    acc
}

fn gemm_wide_portable(a: &[f32], bt: &[f32], out: &mut [f32], d: usize,
                      k: usize, n: usize) {
    for i in 0..d {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot_wide(arow, &bt[j * k..(j + 1) * k]);
        }
    }
}

/// AVX2 tier: 8-lane mul-add over the reduction with 4-column register
/// blocking (each loaded a-vector reused across 4 bt rows).
///
/// # Safety
/// Caller must have verified AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_avx2(a: &[f32], bt: &[f32], out: &mut [f32], d: usize,
                    k: usize, n: usize) {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    let k8 = k & !7;
    let n4 = n & !3;
    for i in 0..d {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let rows = [
                &bt[j * k..(j + 1) * k],
                &bt[(j + 1) * k..(j + 2) * k],
                &bt[(j + 2) * k..(j + 3) * k],
                &bt[(j + 3) * k..(j + 4) * k],
            ];
            let mut vacc = [_mm256_setzero_ps(); 4];
            let mut kk = 0;
            while kk < k8 {
                let av = _mm256_loadu_ps(arow.as_ptr().add(kk));
                for (c, br) in rows.iter().enumerate() {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(kk));
                    vacc[c] =
                        _mm256_add_ps(vacc[c], _mm256_mul_ps(av, bv));
                }
                kk += 8;
            }
            for (c, br) in rows.iter().enumerate() {
                let mut acc = hsum(vacc[c]);
                for t in k8..k {
                    acc += arow[t] * br[t];
                }
                orow[j + c] = acc;
            }
            j += 4;
        }
        while j < n {
            let br = &bt[j * k..(j + 1) * k];
            let mut vacc = _mm256_setzero_ps();
            let mut kk = 0;
            while kk < k8 {
                let av = _mm256_loadu_ps(arow.as_ptr().add(kk));
                let bv = _mm256_loadu_ps(br.as_ptr().add(kk));
                vacc = _mm256_add_ps(vacc, _mm256_mul_ps(av, bv));
                kk += 8;
            }
            let mut acc = hsum(vacc);
            for t in k8..k {
                acc += arow[t] * br[t];
            }
            orow[j] = acc;
            j += 1;
        }
    }
}

/// Widest float gemm available on this CPU (AVX2, else the portable
/// 8-wide fallback).  Deterministic for a fixed build + CPU; on ±1
/// inputs it is exactly equal to every other float kernel (integer
/// sums are exact in f32 at these reduction lengths).
pub fn gemm_simd(a: &[f32], bt: &[f32], out: &mut [f32], d: usize,
                 k: usize, n: usize) {
    assert_eq!(a.len(), d * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), d * n);
    #[cfg(target_arch = "x86_64")]
    {
        if crate::bitops::avx2_available() {
            unsafe { gemm_avx2(a, bt, out, d, k, n) };
            return;
        }
    }
    gemm_wide_portable(a, bt, out, d, k, n);
}

/// Which float kernel to run (mirrors [`crate::bitops::XnorImpl`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmImpl {
    /// Plain dot-product loops (the paper's Control Group).
    Naive,
    /// Cache/register-blocked kernel.
    Blocked,
    /// AVX2 when detected, else the portable 8-wide fallback.
    Simd,
}

/// Dispatch one `[D, k] x [N, k]` float gemm to the selected kernel.
pub fn gemm_f32(
    a: &[f32],
    bt: &[f32],
    out: &mut [f32],
    d: usize,
    k: usize,
    n: usize,
    imp: GemmImpl,
) {
    match imp {
        GemmImpl::Naive => gemm_naive(a, bt, out, d, k, n),
        GemmImpl::Blocked => gemm_blocked(a, bt, out, d, k, n),
        GemmImpl::Simd => gemm_simd(a, bt, out, d, k, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    fn reference(a: &[f32], bt: &[f32], d: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f64; d * n];
        for i in 0..d {
            for j in 0..n {
                out[i * n + j] = (0..k)
                    .map(|kk| a[i * k + kk] as f64 * bt[j * k + kk] as f64)
                    .sum();
            }
        }
        out.into_iter().map(|v| v as f32).collect()
    }

    fn check(d: usize, k: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.normal_vec(d * k);
        let bt = rng.normal_vec(n * k);
        let want = reference(&a, &bt, d, k, n);
        for imp in [GemmImpl::Naive, GemmImpl::Blocked, GemmImpl::Simd] {
            let mut got = vec![0.0f32; d * n];
            gemm_f32(&a, &bt, &mut got, d, k, n, imp);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                    "{imp:?} d={d} k={k} n={n}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn matches_reference() {
        for (d, k, n) in [(1, 1, 1), (3, 7, 5), (4, 32, 4), (5, 100, 9),
                          (8, 64, 8), (2, 300, 3)] {
            check(d, k, n, (d + k + n) as u64);
        }
    }

    #[test]
    fn exact_on_binary_values() {
        let mut rng = Rng::new(3);
        let (d, k, n) = (6, 95, 7);
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut naive = vec![0.0f32; d * n];
        let mut blocked = vec![0.0f32; d * n];
        let mut simd = vec![0.0f32; d * n];
        gemm_naive(&a, &bt, &mut naive, d, k, n);
        gemm_blocked(&a, &bt, &mut blocked, d, k, n);
        gemm_simd(&a, &bt, &mut simd, d, k, n);
        assert_eq!(naive, blocked); // integer-valued: exact equality
        assert_eq!(naive, simd);
        for v in naive {
            assert!(v.abs() <= k as f32 && v.fract() == 0.0);
        }
    }

    #[test]
    fn agrees_with_xnor_gemm_on_signs() {
        use crate::bitops::{pack_rows, xnor_gemm, XnorImpl};
        let mut rng = Rng::new(11);
        let (d, k, n) = (5, 70, 6);
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut fout = vec![0.0f32; d * n];
        gemm_naive(&a, &bt, &mut fout, d, k, n);
        let mut iout = vec![0i32; d * n];
        xnor_gemm(
            &pack_rows(&a, d, k),
            &pack_rows(&bt, n, k),
            &mut iout,
            XnorImpl::Blocked,
        );
        let f_as_i: Vec<i32> = fout.iter().map(|&v| v as i32).collect();
        assert_eq!(f_as_i, iout);
    }
}
