//! The paper's Table 2, regenerated.
//!
//! Paper (GTX 1080 Ti / Xeon E5-2620, CIFAR-10 test set, 10k images):
//!
//! |               | CPU   | GPU    |
//! | PyTorch       | 301s  | 1.70s  |
//! | Our Kernel    | 243s  | 3.57s  |
//! | Control Group | 1093s | 11.23s |
//!
//! Here (DESIGN.md §5): the CPU column is the native rust engine; the
//! GPU column is the XLA/PJRT executables (pallas-lowered HLO).  We time
//! a subset and extrapolate to the full 10k-image test set; the claim
//! under reproduction is the RATIO structure (xnor ≈ 4.5x control on
//! CPU, ≈ 3x on the accelerator runtime, vendor kernel fastest there),
//! not the absolute seconds of the authors' 2019 testbed.

use anyhow::Result;

use crate::bitops::XnorImpl;
use crate::data::Dataset;
use crate::model::{BnnEngine, EngineKernel};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::utils::Stopwatch;

use super::Table;

/// Test-set size the paper's Table 2 timings cover.
pub const PAPER_TEST_IMAGES: usize = 10_000;

/// Paper-reported seconds (CPU, GPU) per row.
pub const PAPER: [(&str, f64, f64); 3] = [
    ("PyTorch (optimized)", 301.0, 1.70),
    ("Our Kernel (xnor)", 243.0, 3.57),
    ("Control Group", 1093.0, 11.23),
];

/// Sampling knobs for regenerating Table 2.
#[derive(Debug, Clone)]
pub struct Table2Options {
    /// Images timed on the native arm for the fast kernels.
    pub native_images: usize,
    /// Images timed for the native control group (naive gemm is slow).
    pub native_control_images: usize,
    /// Batches of 8 timed on the PJRT arm.
    pub pjrt_batches: usize,
    /// Weight set ("full" reproduces the paper's model).
    pub weights: String,
}

impl Default for Table2Options {
    fn default() -> Self {
        Self {
            native_images: 16,
            native_control_images: 4,
            pjrt_batches: 2,
            weights: "full".into(),
        }
    }
}

/// One measured Table-2 row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Kernel arm label.
    pub name: &'static str,
    /// Extrapolated seconds for the 10k-image test set.
    pub native_s: f64,
    /// Extrapolated PJRT seconds (NaN in non-`pjrt` builds).
    pub pjrt_s: f64,
}

/// The regenerated table.
#[derive(Debug, Clone)]
pub struct Table2Result {
    /// Measured rows, paper order.
    pub rows: Vec<Table2Row>,
}

impl Table2Result {
    /// The row whose arm name starts with `name_prefix`.
    pub fn row(&self, name_prefix: &str) -> &Table2Row {
        self.rows
            .iter()
            .find(|r| r.name.starts_with(name_prefix))
            .expect("row")
    }

    /// Whether the PJRT column was actually measured (false in
    /// non-`pjrt` builds, where it is NaN-filled).
    pub fn has_pjrt(&self) -> bool {
        self.rows.iter().all(|r| !r.pjrt_s.is_nan())
    }

    /// Speedup of the xnor kernel over the control group.
    pub fn native_speedup(&self) -> f64 {
        self.row("Control").native_s / self.row("Our").native_s
    }

    /// Speedup of the xnor kernel over the control group on PJRT.
    pub fn pjrt_speedup(&self) -> f64 {
        self.row("Control").pjrt_s / self.row("Our").pjrt_s
    }

    /// Render the paper-style table with measured + paper columns.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Table 2 — BNN inference, full test set (10,000 images, seconds)",
            &["kernel", "native rust (CPU)", "XLA/PJRT (accel.)",
              "paper CPU", "paper GPU"],
        );
        for (row, (pname, pcpu, pgpu)) in self.rows.iter().zip(PAPER) {
            debug_assert_eq!(&row.name[..3], &pname[..3]);
            t.row(&[
                row.name.to_string(),
                format!("{:.1}s", row.native_s),
                if row.pjrt_s.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.1}s", row.pjrt_s)
                },
                format!("{pcpu:.0}s"),
                format!("{pgpu:.2}s"),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "\nxnor vs control speedup:  native {:.2}x (paper: {:.2}x)",
            self.native_speedup(),
            PAPER[2].1 / PAPER[1].1,
        ));
        if self.has_pjrt() {
            out.push_str(&format!(
                "   pjrt {:.2}x (paper: {:.2}x)",
                self.pjrt_speedup(),
                PAPER[2].2 / PAPER[1].2,
            ));
        } else {
            out.push_str("   pjrt — (not built)");
        }
        out.push('\n');
        out
    }
}

/// Time per image on the native arm, through the compiled plan path
/// (compile once, then steady-state `Session::run` — the serving
/// configuration the paper's Table 2 is about).
fn time_native(
    engine: &BnnEngine,
    ds: &Dataset,
    kernel: EngineKernel,
    images: usize,
) -> f64 {
    let mut session = engine.plan(kernel, 1).unwrap().session();
    // Warmup on one image.
    let x = ds.normalized(0, 1);
    std::hint::black_box(session.run(&x));
    let sw = Stopwatch::start();
    for i in 0..images {
        let x = ds.normalized(i, i + 1);
        std::hint::black_box(session.run(&x));
    }
    sw.elapsed_secs() / images as f64
}

/// Run the whole experiment.  `log` receives progress lines.
pub fn run(
    artifacts: &std::path::Path,
    opts: &Table2Options,
    mut log: impl FnMut(&str),
) -> Result<Table2Result> {
    let ds = Dataset::load(artifacts.join("dataset_test.bin"))?;
    let engine = BnnEngine::load(
        artifacts.join(format!("weights_{}.bkw", opts.weights)),
    )?;

    // --- native arm ---------------------------------------------------------
    let mut native = Vec::new();
    for (kernel, images) in [
        (EngineKernel::Optimized, opts.native_images),
        (EngineKernel::Xnor(XnorImpl::Auto), opts.native_images),
        (EngineKernel::Control, opts.native_control_images),
    ] {
        log(&format!("[native] timing {} over {} images...",
                     kernel.name(), images));
        let per_image = time_native(&engine, &ds, kernel, images);
        log(&format!("[native] {}: {:.1} ms/image", kernel.name(),
                     per_image * 1e3));
        native.push(per_image * PAPER_TEST_IMAGES as f64);
    }

    // --- PJRT arm (needs the `pjrt` feature; NaN-filled otherwise so
    // the native results survive in default builds) ---------------------------
    #[cfg(feature = "pjrt")]
    let pjrt = {
        let mut rt = Runtime::new(artifacts)?;
        let mut pjrt = Vec::new();
        for variant in ["optimized", "xnor", "control"] {
            log(&format!("[pjrt] compiling bnn_{}_{}_b8...",
                         opts.weights, variant));
            let model = rt.load_by(&opts.weights, variant, 8)?;
            let x = ds.normalized(0, 8);
            std::hint::black_box(model.infer(&x)?); // warmup (first exec)
            let sw = Stopwatch::start();
            for b in 0..opts.pjrt_batches {
                let x = ds.normalized(b * 8, (b + 1) * 8);
                std::hint::black_box(model.infer(&x)?);
            }
            let per_image =
                sw.elapsed_secs() / (8 * opts.pjrt_batches) as f64;
            log(&format!("[pjrt] {variant}: {:.1} ms/image",
                         per_image * 1e3));
            pjrt.push(per_image * PAPER_TEST_IMAGES as f64);
        }
        pjrt
    };
    #[cfg(not(feature = "pjrt"))]
    let pjrt = {
        log("[pjrt] skipped: built without the `pjrt` feature");
        vec![f64::NAN; native.len()]
    };

    Ok(Table2Result {
        rows: vec![
            Table2Row { name: "PyTorch (optimized)", native_s: native[0],
                        pjrt_s: pjrt[0] },
            Table2Row { name: "Our Kernel (xnor)", native_s: native[1],
                        pjrt_s: pjrt[1] },
            Table2Row { name: "Control Group", native_s: native[2],
                        pjrt_s: pjrt[2] },
        ],
    })
}
