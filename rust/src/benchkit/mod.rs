//! Hand-rolled measurement harness (offline substrate for criterion).
//!
//! `cargo bench` targets use [`bench`] / [`bench_n`] for warmed-up,
//! repeated timing with mean/min/percentile summaries, and
//! [`Table`] to print the paper-style result tables.

pub mod table2;

use crate::utils::timer::{mean, percentile};
use crate::utils::Stopwatch;

/// One measured routine.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label of the measured routine.
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub samples: Vec<f64>,
    /// Work items per iteration (images, elements...) for throughput.
    pub items_per_iter: f64,
}

impl Measurement {
    /// Mean per-iteration seconds.
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }

    /// Fastest iteration, seconds.
    pub fn min_s(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Median iteration, seconds.
    pub fn p50_s(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }

    /// Items per second at the mean iteration time.
    pub fn throughput(&self) -> f64 {
        self.items_per_iter / self.mean_s()
    }

    /// One-line mean/min/p50 summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<28} mean {:>10.4} ms   min {:>10.4} ms   p50 {:>10.4} ms",
            self.name,
            self.mean_s() * 1e3,
            self.min_s() * 1e3,
            self.p50_s() * 1e3,
        )
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench_n<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_secs());
    }
    Measurement { name: name.to_string(), samples, items_per_iter }
}

/// Adaptive variant: picks an iteration count that spends roughly
/// `budget_s` seconds, with at least `min_iters` runs.
pub fn bench<F: FnMut()>(
    name: &str,
    budget_s: f64,
    min_iters: usize,
    items_per_iter: f64,
    mut f: F,
) -> Measurement {
    // One calibration run (also serves as warmup).
    let sw = Stopwatch::start();
    f();
    let once = sw.elapsed_secs().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(min_iters, 10_000);
    bench_n(name, 1, iters, items_per_iter, f)
}

/// Paper-style fixed-width table printer.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title row and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (cell count must match the headers).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render to an aligned fixed-width string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!(
            "|{}|\n",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_counts_samples() {
        let m = bench_n("t", 1, 5, 2.0, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn adaptive_bench_respects_min() {
        let m = bench("t", 0.0, 3, 1.0, || {});
        assert!(m.samples.len() >= 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Results", &["kernel", "CPU"]);
        t.row(&["xnor".into(), "1.0s".into()]);
        t.row(&["control-group".into(), "4.5s".into()]);
        let s = t.render();
        assert!(s.contains("Results"));
        assert!(s.contains("control-group"));
        // column alignment: header and both data rows same width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4); // header, separator, 2 rows
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
