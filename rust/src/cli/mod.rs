//! Hand-rolled CLI argument parser (offline substrate for clap).
//!
//! Grammar: `bitkernel <subcommand> [--flag value | --switch]...`.
//! Flags are declared up front so `--help` output and unknown-flag
//! errors come for free.

use std::collections::BTreeMap;

/// Declaration of one `--flag` (value-taking or switch).
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// Whether the flag consumes the next argument as its value.
    pub takes_value: bool,
    /// Default value when the flag is absent (value flags only).
    pub default: Option<&'static str>,
    /// One-line description for `--help`.
    pub help: &'static str,
}

/// Parsed arguments for one subcommand.  Value flags may repeat
/// ([`Args::get_all`] sees every occurrence in order; [`Args::get`]
/// the last one — the usual "later flags win" CLI convention).
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Explicit occurrences per flag, in argv order.
    values: BTreeMap<String, Vec<String>>,
    /// Declared defaults (consulted when no explicit value was given).
    defaults: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Argument-parse failures.
#[derive(Debug, thiserror::Error)]
pub enum CliError {
    /// A flag that was never declared.
    #[error("unknown flag '--{0}'")]
    UnknownFlag(String),
    /// A value-taking flag at the end of the argument list.
    #[error("flag '--{0}' needs a value")]
    MissingValue(String),
    /// A value that failed to parse as the requested type.
    #[error("bad value for '--{0}': {1}")]
    BadValue(String, String),
    /// A bare argument (this grammar has none).
    #[error("unexpected positional argument '{0}'")]
    Positional(String),
}

impl Args {
    /// Parse `argv` (after the subcommand) against the declared flags.
    pub fn parse(
        argv: &[String],
        specs: &[FlagSpec],
    ) -> Result<Self, CliError> {
        let mut out = Args::default();
        for s in specs {
            if let Some(d) = s.default {
                out.defaults.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let Some(name) = arg.strip_prefix("--") else {
                return Err(CliError::Positional(arg.clone()));
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| CliError::UnknownFlag(name.to_string()))?;
            if spec.takes_value {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
                out.values
                    .entry(name.to_string())
                    .or_default()
                    .push(v.clone());
            } else {
                out.switches.push(name.to_string());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Value of a flag: the LAST explicit occurrence, else the
    /// declared default.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values
            .get(name)
            .and_then(|v| v.last())
            .or_else(|| self.defaults.get(name))
            .map(String::as_str)
    }

    /// Every explicit occurrence of a repeatable value flag, in argv
    /// order (empty when the flag was never passed — defaults are NOT
    /// synthesized here, so callers can tell "absent" apart).
    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Value of a flag, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Value of a flag parsed as usize, or `default` when absent.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| {
                CliError::BadValue(name.to_string(), format!("{e}"))
            }),
        }
    }

    /// Whether a switch flag was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Peel one leading positional argument (a bare word before any
/// `--flag`) off `argv`, returning it and the remaining arguments.
/// Subcommands with an optional positional operand (`describe [spec]`,
/// `mount <name>=<path>`, `unmount <name>`, ...) call this before
/// [`Args::parse`], which itself accepts no positionals.
pub fn take_positional(argv: &[String]) -> (Option<String>, Vec<String>) {
    match argv.first() {
        Some(a) if !a.starts_with("--") => {
            (Some(a.clone()), argv[1..].to_vec())
        }
        _ => (None, argv.to_vec()),
    }
}

/// Render a --help block for a subcommand.
pub fn render_help(cmd: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("bitkernel {cmd} — {about}\n\nflags:\n");
    for s in specs {
        let v = if s.takes_value { " <value>" } else { "" };
        let d = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("  --{}{v:<12} {}{d}\n", s.name, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: &[FlagSpec] = &[
        FlagSpec { name: "batch", takes_value: true, default: Some("8"),
                   help: "batch size" },
        FlagSpec { name: "verbose", takes_value: false, default: None,
                   help: "log more" },
    ];

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::parse(&argv(&[]), SPECS).unwrap();
        assert_eq!(a.get_usize("batch", 0).unwrap(), 8);
        let a = Args::parse(&argv(&["--batch", "32"]), SPECS).unwrap();
        assert_eq!(a.get_usize("batch", 0).unwrap(), 32);
    }

    #[test]
    fn repeated_flags_collect_in_order_and_last_wins() {
        let a = Args::parse(
            &argv(&["--batch", "4", "--batch", "16"]),
            SPECS,
        )
        .unwrap();
        assert_eq!(a.get("batch"), Some("16"));
        assert_eq!(a.get_all("batch"), &["4".to_string(), "16".into()]);
        // Defaults never leak into get_all.
        let a = Args::parse(&argv(&[]), SPECS).unwrap();
        assert_eq!(a.get("batch"), Some("8"));
        assert!(a.get_all("batch").is_empty());
    }

    #[test]
    fn switches() {
        let a = Args::parse(&argv(&["--verbose"]), SPECS).unwrap();
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn errors() {
        assert!(matches!(Args::parse(&argv(&["--nope"]), SPECS),
                         Err(CliError::UnknownFlag(_))));
        assert!(matches!(Args::parse(&argv(&["--batch"]), SPECS),
                         Err(CliError::MissingValue(_))));
        assert!(matches!(Args::parse(&argv(&["stray"]), SPECS),
                         Err(CliError::Positional(_))));
        let a = Args::parse(&argv(&["--batch", "x"]), SPECS).unwrap();
        assert!(matches!(a.get_usize("batch", 0),
                         Err(CliError::BadValue(..))));
    }

    #[test]
    fn take_positional_peels_only_a_leading_bare_word() {
        let (pos, rest) =
            take_positional(&argv(&["name=path", "--batch", "4"]));
        assert_eq!(pos.as_deref(), Some("name=path"));
        assert_eq!(rest, argv(&["--batch", "4"]));
        let (pos, rest) = take_positional(&argv(&["--batch", "4"]));
        assert_eq!(pos, None);
        assert_eq!(rest, argv(&["--batch", "4"]));
        let (pos, rest) = take_positional(&[]);
        assert_eq!(pos, None);
        assert!(rest.is_empty());
    }

    #[test]
    fn help_renders() {
        let h = render_help("serve", "run the server", SPECS);
        assert!(h.contains("--batch"));
        assert!(h.contains("default: 8"));
    }
}
