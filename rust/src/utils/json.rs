//! Minimal JSON parser + writer (offline substrate for serde_json).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for the artifact manifest and the HTTP API.  Parsing is recursive
//! descent over bytes; values are an owned enum tree.

use std::collections::BTreeMap;
use std::fmt;

/// An owned JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Whether this is `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Builder helper: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let c = self
                                    .bump()
                                    .ok_or_else(|| self.err("bad \\u"))?;
                                code = code * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo — ok\"").unwrap(),
            Json::Str("héllo — ok".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\nc".into());
        assert_eq!(v.to_string(), "\"a\\\"b\\nc\"");
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"models":[{"name":"m","inputs":[{"dtype":"u32","shape":[2,3],"logical_k":70}]}]}"#;
        let v = Json::parse(src).unwrap();
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        let inp = &m.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("logical_k").unwrap().as_usize(), Some(70));
        let dims: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![2, 3]);
    }
}
