//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! Used by tests, the property harness, synthetic workload generation and
//! the load generator.  Not cryptographic; deterministic per seed so every
//! bench and test is reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (same seed, same stream).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (the high half of [`Rng::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// A vector of standard normals (the usual random-tensor helper).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// A {-1.0, +1.0} vector (binarized-tensor helper).
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { -1.0 } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sign_vec_balanced() {
        let mut r = Rng::new(5);
        let xs = r.sign_vec(10_000);
        let pos = xs.iter().filter(|&&x| x > 0.0).count();
        assert!((4500..5500).contains(&pos), "{pos}");
        assert!(xs.iter().all(|&x| x == 1.0 || x == -1.0));
    }
}
