//! Timing helpers: a stopwatch and percentile summaries over samples.

use std::time::{Duration, Instant};

/// A simple stopwatch around `Instant`.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since start, in seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Time since start, in milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    /// Return the elapsed time and start over.
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
///
/// `q` in [0, 1]; empty input returns 0.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((q * (sorted.len() - 1) as f64).floor() as usize)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// Mean of a sample set (0 for empty input).
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<f64>() / samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert!((percentile(&xs, 0.99) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn percentile_unsorted_input() {
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 0.5), 3.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }
}
