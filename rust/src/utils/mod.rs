//! Support substrates: PRNG, timing, JSON, config, logging, thread pool.
//!
//! The build environment is offline (no crates.io), so everything a crate
//! would normally pull in — rand, serde_json, rayon, env_logger — is
//! implemented here, small and tested.

pub mod config;
pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
