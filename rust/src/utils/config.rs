//! `key = value` config-file parser (offline substrate for a toml crate).
//!
//! Grammar: one `key = value` per line, `#` comments, blank lines ignored.
//! Values stay strings; typed getters parse on demand.  Used by the
//! serving coordinator (`bitkernel serve --config <file>`).

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed `key = value` configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Config parse/typing failures.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    /// A line that is not `key = value`, a comment, or blank.
    #[error("line {0}: expected 'key = value', got '{1}'")]
    Syntax(usize, String),
    /// A typed getter could not parse the stored string.
    #[error("key '{0}': {1}")]
    Type(String, String),
    /// Underlying file I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Config {
    /// Parse config text (one `key = value` per line).
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Syntax(i + 1, raw.to_string()))?;
            values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { values })
    }

    /// Parse a config file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Overlay: `other` wins on conflicts (CLI-over-file semantics).
    pub fn merged(mut self, other: &Config) -> Self {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    /// Set (or overwrite) one key.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// Raw string value of `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Raw string value of `key`, or `default`.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// `key` parsed as usize, or `default` when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| ConfigError::Type(key.into(), format!("{e}"))),
        }
    }

    /// `key` parsed as f64, or `default` when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| ConfigError::Type(key.into(), format!("{e}"))),
        }
    }

    /// `key` parsed as bool (`true/1/yes` vs `false/0/no`), or
    /// `default` when absent.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(s) => Err(ConfigError::Type(key.into(), format!("bad bool '{s}'"))),
        }
    }

    /// Every configured key, in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = Config::parse("a = 1\n# comment\nb = hello world # tail\n\n")
            .unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("hello world"));
        assert_eq!(c.get_usize("a", 0).unwrap(), 1);
    }

    #[test]
    fn defaults_and_types() {
        let c = Config::parse("x = 2.5\nflag = yes\n").unwrap();
        assert_eq!(c.get_f64("x", 0.0).unwrap(), 2.5);
        assert!(c.get_bool("flag", false).unwrap());
        assert_eq!(c.get_usize("missing", 7).unwrap(), 7);
        assert!(c.get_usize("x", 0).is_err());
    }

    #[test]
    fn rejects_bad_line() {
        assert!(Config::parse("just a line\n").is_err());
    }

    #[test]
    fn merge_overrides() {
        let base = Config::parse("a = 1\nb = 2\n").unwrap();
        let over = Config::parse("b = 3\n").unwrap();
        let m = base.merged(&over);
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("b"), Some("3"));
    }
}
