//! Fixed-size thread pool (offline substrate for rayon/tokio).
//!
//! Work items are boxed closures on an mpsc channel guarded by a mutex on
//! the receiver (classic shared-queue pool).  `scope_chunks` is the
//! data-parallel helper the threaded xnor-gemm uses: it splits an index
//! range into contiguous chunks and runs one std::thread::scope task per
//! chunk — no pool needed, no 'static bound on the closure.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker panicked");
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(chunk_start, chunk_end)` over `0..n` split into `threads`
/// contiguous chunks, in parallel, borrowing from the caller's stack.
pub fn scope_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..103).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(103, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_single_thread_and_empty() {
        let sum = AtomicUsize::new(0);
        scope_chunks(10, 1, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
        scope_chunks(0, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn scope_chunks_more_threads_than_items() {
        let sum = AtomicUsize::new(0);
        scope_chunks(3, 16, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3);
    }
}
