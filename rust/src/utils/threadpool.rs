//! Fixed-size thread pool (offline substrate for rayon/tokio).
//!
//! Work items are boxed closures on an mpsc channel guarded by a mutex on
//! the receiver (classic shared-queue pool).  Two data-parallel helpers
//! drive the threaded xnor-gemm:
//!
//! * [`scope_chunks`] — splits an index range into contiguous chunks and
//!   runs one `std::thread::scope` task per chunk; no pool needed, no
//!   `'static` bound on the closure, but pays a thread spawn per chunk
//!   per call.
//! * [`ThreadPool::run_chunks`] — the same split executed on the pool's
//!   persistent workers (the plan/session serving path: compile once,
//!   then steady-state inference never spawns a thread).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `threads` persistent workers.
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => {
                            // A panicking job must not kill the worker:
                            // pools are long-lived (a Plan owns one for
                            // all its Sessions).  Caller-side
                            // propagation is the submitter's business —
                            // `run_chunks` re-panics via its DoneGuard
                            // latch.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                        }
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Enqueue one job for any free worker.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker panicked");
    }

    /// Number of worker threads.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// Whether the pool has no workers (never true for a live pool).
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Run `f(chunk_start, chunk_end)` over `0..n` split into (at most)
    /// one contiguous chunk per worker, on the pool's persistent
    /// threads, blocking until every chunk completes.  The closure may
    /// borrow from the caller's stack — the pooled equivalent of
    /// [`scope_chunks`].
    ///
    /// Must not be called from a pool worker (the caller would block a
    /// worker the chunks need).
    pub fn run_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let parts = self.len().clamp(1, n);
        if parts == 1 {
            f(0, n);
            return;
        }
        // Erase the closure's lifetime so jobs satisfy the queue's
        // `'static` bound.  Sound: the completion latch below is
        // drained before this frame returns, so the borrow outlives
        // every job (a panicking job still signals via its DoneGuard
        // during unwind).
        let f_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let chunk = n.div_ceil(parts);
        let (done_tx, done_rx) = mpsc::channel::<bool>();
        let mut jobs = 0usize;
        for t in 0..parts {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let guard = DoneGuard { tx: done_tx.clone(), ok: false };
            self.execute(move || {
                let mut guard = guard;
                f_static(lo, hi);
                guard.ok = true;
            });
            jobs += 1;
        }
        drop(done_tx);
        let mut all_ok = true;
        for _ in 0..jobs {
            all_ok &= done_rx
                .recv()
                .expect("pool worker exited without completing its chunk");
        }
        assert!(all_ok, "a pooled chunk panicked");
    }
}

/// Completion-latch token: signals even when the chunk panics (during
/// unwind, with `ok: false`), so [`ThreadPool::run_chunks`] never
/// deadlocks on a poisoned worker and panics propagate to the caller.
struct DoneGuard {
    tx: mpsc::Sender<bool>,
    ok: bool,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(self.ok);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(chunk_start, chunk_end)` over `0..n` split into `threads`
/// contiguous chunks, in parallel, borrowing from the caller's stack.
pub fn scope_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join: all post-panic jobs still ran
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn run_chunks_propagates_chunk_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(4, |lo, _| {
                if lo == 0 {
                    panic!("chunk failed");
                }
            });
        }));
        assert!(r.is_err(), "chunk panic must reach the caller");
        let sum = AtomicUsize::new(0);
        pool.run_chunks(3, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn run_chunks_covers_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> =
            (0..103).map(|_| AtomicUsize::new(0)).collect();
        pool.run_chunks(103, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // Reusable: a second dispatch on the same workers.
        let sum = AtomicUsize::new(0);
        pool.run_chunks(10, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
        // Degenerate inputs.
        pool.run_chunks(0, |_, _| panic!("must not run"));
        pool.run_chunks(1, |lo, hi| {
            assert_eq!((lo, hi), (0, 1));
        });
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..103).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(103, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_single_thread_and_empty() {
        let sum = AtomicUsize::new(0);
        scope_chunks(10, 1, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
        scope_chunks(0, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn scope_chunks_more_threads_than_items() {
        let sum = AtomicUsize::new(0);
        scope_chunks(3, 16, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3);
    }
}
