//! Tiny leveled logger to stderr (offline substrate for env_logger).
//!
//! Level comes from `BITKERNEL_LOG` (error|warn|info|debug|trace),
//! default `info`.  Thread-safe via a single atomic; no allocation on
//! disabled levels thanks to the macro guard.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable / dropped-work conditions.
    Error = 0,
    /// Suspicious but handled conditions.
    Warn = 1,
    /// Operational milestones (default level).
    Info = 2,
    /// Per-request noise.
    Debug = 3,
    /// Everything.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("BITKERNEL_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level as a raw u8 (lazily read from `BITKERNEL_LOG`).
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        init_from_env()
    } else {
        l
    }
}

/// Override the level at runtime.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether messages at `l` are emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Emit one message (used via the `log_*!` macros).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at [`Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::utils::logging::log($crate::utils::logging::Level::Error, format_args!($($t)*)) };
}
/// Log at [`Level::Warn`] with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::utils::logging::log($crate::utils::logging::Level::Warn, format_args!($($t)*)) };
}
/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::utils::logging::log($crate::utils::logging::Level::Info, format_args!($($t)*)) };
}
/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::utils::logging::log($crate::utils::logging::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
