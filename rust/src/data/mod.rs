//! Dataset handling: the BKD1 binary format written by python (the
//! shared ShapeSet-10 splits) plus a native generator for load tests.

pub mod bkd;
pub mod shapeset;

pub use bkd::{normalize_batch, Dataset};
pub use shapeset::random_image;
