//! Native ShapeSet-10-style image generator.
//!
//! Used by the load generator and benches to synthesize request payloads
//! without reading the dataset from disk.  It draws the same 10 shape
//! classes as python/compile/dataset.py but does NOT need to be
//! pixel-identical — accuracy experiments always use the shared BKD1
//! files; this generator only has to look like real traffic.

use crate::utils::Rng;

/// Generated image height.
pub const H: usize = 32;
/// Generated image width.
pub const W: usize = 32;
/// Generated image channels.
pub const C: usize = 3;

/// One uint8 HWC image of the given class (0..10).
pub fn random_image(label: usize, rng: &mut Rng) -> Vec<u8> {
    assert!(label < 10);
    let cy = rng.uniform(10.0, 22.0);
    let cx = rng.uniform(10.0, 22.0);
    let r = rng.uniform(6.0, 12.0);
    let mut fg = [rng.uniform(0.55, 1.0), rng.uniform(0.55, 1.0),
                  rng.uniform(0.55, 1.0)];
    let mut bg = [rng.uniform(0.0, 0.45), rng.uniform(0.0, 0.45),
                  rng.uniform(0.0, 0.45)];
    if rng.next_f32() < 0.3 {
        std::mem::swap(&mut fg, &mut bg);
    }
    let period = 3 + rng.below(3) as i32;
    let flip = rng.next_f32() < 0.5;

    let mut out = vec![0u8; H * W * C];
    for y in 0..H {
        for x in 0..W {
            let yy = y as f32 - cy;
            let xx = x as f32 - cx;
            let m: f32 = match label {
                0 => f32::from(yy * yy + xx * xx <= r * r),
                1 => f32::from(yy.abs() <= r * 0.8 && xx.abs() <= r * 0.8),
                2 => f32::from(
                    yy.abs() <= r * 0.7 && xx.abs() <= (yy + r * 0.7) * 0.6,
                ),
                3 => {
                    let t = r * 0.3;
                    f32::from(
                        (yy.abs() <= t || xx.abs() <= t)
                            && yy.abs() <= r
                            && xx.abs() <= r,
                    )
                }
                4 => {
                    let d2 = yy * yy + xx * xx;
                    f32::from(d2 <= r * r && d2 >= (r * 0.55) * (r * 0.55))
                }
                5 => f32::from((y as i32 / period) % 2 == 0),
                6 => f32::from((x as i32 / period) % 2 == 0),
                7 => f32::from(
                    ((y as i32 / period) + (x as i32 / period)) % 2 == 0,
                ),
                8 => f32::from(
                    (y as i32 % (period + 2)) < 2
                        && (x as i32 % (period + 2)) < 2,
                ),
                9 => {
                    let g = (y + x) as f32 / (H + W - 2) as f32;
                    if flip {
                        1.0 - g
                    } else {
                        g
                    }
                }
                _ => unreachable!(),
            };
            for ch in 0..C {
                let v = m * fg[ch] + (1.0 - m) * bg[ch]
                    + 0.06 * rng.normal();
                out[(y * W + x) * C + ch] =
                    (v.clamp(0.0, 1.0) * 255.0) as u8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = random_image(0, &mut Rng::new(1));
        let b = random_image(0, &mut Rng::new(1));
        assert_eq!(a.len(), H * W * C);
        assert_eq!(a, b);
    }

    #[test]
    fn all_classes_render() {
        let mut rng = Rng::new(2);
        for label in 0..10 {
            let img = random_image(label, &mut rng);
            // non-degenerate: some pixel variation
            let min = *img.iter().min().unwrap();
            let max = *img.iter().max().unwrap();
            assert!(max > min, "class {label} degenerate");
        }
    }

    #[test]
    fn classes_differ_on_average() {
        let mut rng = Rng::new(3);
        let mean = |l: usize, rng: &mut Rng| -> f64 {
            let mut acc = 0f64;
            for _ in 0..8 {
                let img = random_image(l, rng);
                acc += img.iter().map(|&v| v as f64).sum::<f64>()
                    / img.len() as f64;
            }
            acc / 8.0
        };
        let m5 = mean(5, &mut rng); // stripes: ~half fg
        let m8 = mean(8, &mut rng); // dot grid: mostly bg
        assert!((m5 - m8).abs() > 5.0, "{m5} vs {m8}");
    }
}
