//! BKD1 dataset loader (mirror of python/compile/dataset.py).
//!
//! ```text
//!     magic  b"BKD1"
//!     u32le  count, height, width, channels
//!     count * { u8 label, h*w*c u8 pixels (HWC row-major) }
//! ```

use std::io::Read;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::tensor::Tensor;

/// An in-memory image dataset (uint8 HWC + labels).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Number of images.
    pub count: usize,
    /// Image height in pixels.
    pub height: usize,
    /// Image width in pixels.
    pub width: usize,
    /// Color channels per pixel.
    pub channels: usize,
    /// count * h*w*c bytes, HWC row-major per image.
    pub pixels: Vec<u8>,
    /// One class label per image.
    pub labels: Vec<u8>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

impl Dataset {
    /// Parse a BKD1 stream.
    pub fn parse(mut r: impl Read) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        ensure!(&magic == b"BKD1", "bad magic {magic:?}");
        let count = read_u32(&mut r)? as usize;
        let height = read_u32(&mut r)? as usize;
        let width = read_u32(&mut r)? as usize;
        let channels = read_u32(&mut r)? as usize;
        ensure!(count < 10_000_000 && height * width * channels < 1 << 24,
                "implausible dims");
        let img_bytes = height * width * channels;
        let mut pixels = vec![0u8; count * img_bytes];
        let mut labels = vec![0u8; count];
        for i in 0..count {
            let mut lab = [0u8; 1];
            r.read_exact(&mut lab).context("label")?;
            labels[i] = lab[0];
            r.read_exact(&mut pixels[i * img_bytes..(i + 1) * img_bytes])
                .context("pixels")?;
        }
        Ok(Self { count, height, width, channels, pixels, labels })
    }

    /// Load a BKD1 file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Self::parse(std::io::BufReader::new(f))
    }

    /// View of one image's raw HWC bytes.
    pub fn image(&self, i: usize) -> &[u8] {
        let n = self.height * self.width * self.channels;
        &self.pixels[i * n..(i + 1) * n]
    }

    /// Normalize images `lo..hi` into a float NCHW tensor in [-1, 1].
    pub fn normalized(&self, lo: usize, hi: usize) -> Tensor {
        assert!(hi <= self.count && lo <= hi);
        normalize_batch(
            &self.pixels[lo * self.height * self.width * self.channels
                ..hi * self.height * self.width * self.channels],
            hi - lo,
            self.height,
            self.width,
            self.channels,
        )
    }
}

/// uint8 HWC batch -> f32 NCHW in [-1, 1]  (x/127.5 - 1, like python).
pub fn normalize_batch(
    pixels: &[u8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Tensor {
    assert_eq!(pixels.len(), n * h * w * c);
    let mut out = vec![0.0f32; n * c * h * w];
    for i in 0..n {
        let img = &pixels[i * h * w * c..(i + 1) * h * w * c];
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out[((i * c + ch) * h + y) * w + x] =
                        img[(y * w + x) * c + ch] as f32 / 127.5 - 1.0;
                }
            }
        }
    }
    Tensor::new(vec![n, c, h, w], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_blob() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(b"BKD1");
        for v in [2u32, 2, 2, 3] {
            out.extend(v.to_le_bytes());
        }
        for i in 0..2u8 {
            out.push(i); // label
            out.extend((0..12).map(|p| p + i * 12)); // pixels
        }
        out
    }

    #[test]
    fn parse_and_views() {
        let ds = Dataset::parse(&sample_blob()[..]).unwrap();
        assert_eq!(ds.count, 2);
        assert_eq!(ds.labels, vec![0, 1]);
        assert_eq!(ds.image(1)[0], 12);
    }

    #[test]
    fn normalize_layout_and_range() {
        // single white pixel at (0,0) channel 2
        let mut px = vec![0u8; 12];
        px[2] = 255;
        let t = normalize_batch(&px, 1, 2, 2, 3);
        assert_eq!(t.shape(), &[1, 3, 2, 2]);
        // channel 2 plane, position (0,0) == +1; everything else == -1
        assert_eq!(t.data()[2 * 4], 1.0);
        assert_eq!(t.data()[0], -1.0);
    }

    #[test]
    fn normalized_range_slices() {
        let ds = Dataset::parse(&sample_blob()[..]).unwrap();
        let t = ds.normalized(1, 2);
        assert_eq!(t.shape(), &[1, 3, 2, 2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut blob = sample_blob();
        blob[1] = b'X';
        assert!(Dataset::parse(&blob[..]).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let blob = sample_blob();
        assert!(Dataset::parse(&blob[..blob.len() - 2]).is_err());
    }
}
