//! BNN architecture description — the rust mirror of
//! python/compile/model.py::ModelConfig.
//!
//! The canonical source of truth at runtime is the `meta.widths` tensor
//! in the BKW1 weight file ([c1..c6, f1, f2, 10]); `from_widths` rebuilds
//! the full spec list from it so rust and python can never drift on
//! scale arithmetic.

/// Input image height and width.
pub const IMAGE_HW: usize = 32;
/// Input image channels.
pub const IMAGE_C: usize = 3;
/// Output classes.
pub const NUM_CLASSES: usize = 10;

/// One convolutional layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvSpec {
    /// Layer name (`conv1`..`conv6`), the weight-file key prefix.
    pub name: String,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Square kernel side.
    pub ksize: usize,
    /// Stride (both dims).
    pub stride: usize,
    /// Zero padding (both dims).
    pub pad: usize,
    /// 2x2 max-pool after this conv.
    pub pool: bool,
    /// Input activations are binarized (all convs except conv1).
    pub binarized: bool,
}

impl ConvSpec {
    /// Gemm reduction length K = Cin * k * k.
    pub fn k(&self) -> usize {
        self.cin * self.ksize * self.ksize
    }
}

/// One fully-connected layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FcSpec {
    /// Layer name (`fc1`..`fc3`), the weight-file key prefix.
    pub name: String,
    /// Input width.
    pub din: usize,
    /// Output width.
    pub dout: usize,
}

/// The whole network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Conv layers, in order.
    pub convs: Vec<ConvSpec>,
    /// Fully-connected layers, in order.
    pub fcs: Vec<FcSpec>,
}

impl ModelConfig {
    /// Rebuild from the widths vector stored in a BKW1 file:
    /// [c1, c2, c3, c4, c5, c6, f1, f2, classes].
    pub fn from_widths(widths: &[u32]) -> anyhow::Result<Self> {
        anyhow::ensure!(widths.len() == 9, "expected 9 widths, got {}",
                        widths.len());
        let w: Vec<usize> = widths.iter().map(|&x| x as usize).collect();
        let chans = [IMAGE_C, w[0], w[1], w[2], w[3], w[4], w[5]];
        let convs = (0..6)
            .map(|i| ConvSpec {
                name: format!("conv{}", i + 1),
                cin: chans[i],
                cout: chans[i + 1],
                ksize: 3,
                stride: 1,
                pad: 1,
                pool: i % 2 == 1, // after conv2, conv4, conv6
                binarized: i != 0,
            })
            .collect();
        let hw = IMAGE_HW / 8; // three 2x2 pools
        let dins = [w[4] * hw * hw, w[6], w[7]];
        let fcs = (0..3)
            .map(|i| FcSpec {
                name: format!("fc{}", i + 1),
                din: dins[i],
                dout: if i == 2 { w[8] } else { w[5 + i + 1] },
            })
            .collect();
        Ok(Self { convs, fcs })
    }

    /// Total learnable parameter count (weights + folded BN affines).
    pub fn param_count(&self) -> usize {
        let conv: usize = self.convs.iter().map(|s| s.cout * s.k()).sum();
        let fc: usize = self.fcs.iter().map(|s| s.din * s.dout).sum();
        let bn: usize = self.convs.iter().map(|s| 2 * s.cout).sum::<usize>()
            + self.fcs.iter().map(|s| 2 * s.dout).sum::<usize>();
        conv + fc + bn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: [u32; 9] = [128, 128, 256, 256, 512, 512, 1024, 1024, 10];

    #[test]
    fn full_scale_matches_paper() {
        let cfg = ModelConfig::from_widths(&FULL).unwrap();
        assert_eq!(cfg.convs.len(), 6);
        assert_eq!(cfg.fcs.len(), 3);
        assert_eq!(cfg.convs[0].cin, 3);
        assert!(!cfg.convs[0].binarized);
        assert!(cfg.convs[1].binarized && cfg.convs[1].pool);
        assert_eq!(cfg.convs[5].cout, 512);
        assert_eq!(cfg.fcs[0].din, 512 * 4 * 4);
        assert_eq!(cfg.fcs[2].dout, 10);
        let p = cfg.param_count();
        assert!((13_000_000..16_000_000).contains(&p), "{p}");
    }

    #[test]
    fn small_scale() {
        let cfg = ModelConfig::from_widths(&[32, 32, 64, 64, 128, 128, 256,
                                             256, 10])
            .unwrap();
        assert_eq!(cfg.fcs[0].din, 128 * 16);
        assert_eq!(cfg.fcs[1].din, 256);
        assert_eq!(cfg.convs[2].k(), 32 * 9);
    }

    #[test]
    fn rejects_bad_length() {
        assert!(ModelConfig::from_widths(&[1, 2, 3]).is_err());
    }
}
