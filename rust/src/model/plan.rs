//! Compiled execution plans — the engine's zero-allocation hot path.
//!
//! [`BnnEngine::plan`] lowers the engine's [`crate::model::NetSpec`]
//! ONCE into a flat [`Op`] program with all kernel dispatch resolved at
//! plan time, and [`Plan::session`] pairs that program with preallocated
//! ping-pong activation buffers, im2col scratch, and packed-activation
//! buffers sized for `max_batch` — so [`Session::run`] performs no heap
//! allocation in steady state (pinned by `tests/plan_session.rs`).
//! Lowering is architecture-generic: any validated spec (arbitrary conv
//! stacks, fc-only nets, non-square inputs, any class count, any
//! per-layer `binarized` pattern) compiles on every arm.
//!
//! Lowering per arm:
//!
//! * **Xnor** — non-binarized layers run float (`im2col` + SIMD gemm;
//!   a deferred BatchNorm materializes first when one is pending);
//!   every binarized conv becomes `encode` (fused im2col + bn + sign +
//!   pack, the PREVIOUS layer's BatchNorm folded into the sign) +
//!   `xnor-gemm` (+ `pool`); a layer boundary feeding a binarized
//!   consumer becomes a fused `bn_sign_pack` epilogue that emits the
//!   next layer's [`PackedMatrix`] directly — no bn'd float activation
//!   is ever materialized between binarized layers.
//! * **Control / Optimized** — the paper's baselines stay unfused
//!   (im2col+sign, float gemm, pool, bn as separate ops) but run
//!   against the same reusable buffers.
//!
//! Every lowering is bit-identical to
//! [`BnnEngine::forward_reference`]: fused ops perform the same f32
//! multiply-adds in the same order and only skip materialization.
//!
//! Lowering is also scheme-aware ([`crate::model::QuantScheme`]):
//! α-scheme layers multiply their per-output-channel scale into the
//! gemm epilogues (col2im / `bn_sign_pack` / bn-rows), ternary layers
//! swap the xnor gemm for the two-plane
//! [`crate::bitops::ternary_gemm`], and real-activation schemes lower
//! every layer down the float arm (their binarized weights are already
//! ±1 in the file).  `rust/tests/scheme_conformance.rs` pins every
//! scheme × kernel arm × topology cell against the oracle.
//!
//! A [`Plan`] holds `Arc`s of the engine's weight/BN buffers, so it is
//! self-contained: the engine may be dropped, plans may be shared, and
//! each worker thread derives its own [`Session`].
//!
//! Kernel selection is part of plan compilation: `Xnor(Auto)` resolves
//! every xnor-gemm op to a concrete impl from its shape (D, K, N at
//! `max_batch`) and the detected CPU features — see
//! [`XnorImpl::resolve`] — or via a one-shot microbench when
//! `BITKERNEL_CALIBRATE=1`.  Ops that resolve to `Threaded` run on a
//! persistent [`ThreadPool`] owned by the plan (shared by its
//! sessions), never on per-call spawned threads.  Auto plans record the
//! chosen impl in their stage names (`conv2:xnor-gemm[threaded8]`).

use std::sync::Arc;

use crate::bitops::{pack_rows_from, ternary_gemm, ternary_gemm_pooled,
                    xnor_gemm, xnor_gemm_pooled, XnorImpl};
use crate::gemm::{gemm_f32, GemmImpl};
use crate::nn::fuse::{alpha_col2im_nchw, alpha_col2im_nchw_i32,
                      bn_rows_from_gemm_f32, bn_rows_from_gemm_f32_alpha,
                      bn_rows_from_gemm_i32, bn_rows_from_gemm_i32_alpha,
                      bn_sign_pack_nchw, bn_sign_pack_rows_f32,
                      bn_sign_pack_rows_f32_alpha, bn_sign_pack_rows_i32,
                      bn_sign_pack_rows_i32_alpha};
use crate::nn::im2col::{col2im_nchw_i32_into, col2im_nchw_into,
                        im2col_pack_bn, im2col_t_into, out_hw};
use crate::nn::norm::bn_affine_nchw_slice;
use crate::nn::pool::maxpool2_into;
use crate::nn::sign_inplace;
use crate::tensor::{PackedMatrix, Tensor};
use crate::utils::threadpool::ThreadPool;
use crate::utils::Stopwatch;

use super::bnn::{BnnEngine, EngineKernel};
use super::spec::{QuantScheme, SpecError};

/// Per-image conv geometry, resolved at plan time.
#[derive(Debug, Clone, Copy)]
struct ConvGeom {
    cin: usize,
    cout: usize,
    ksize: usize,
    stride: usize,
    pad: usize,
    /// Input spatial dims.
    h: usize,
    w: usize,
    /// Output spatial dims.
    oh: usize,
    ow: usize,
}

impl ConvGeom {
    fn k(&self) -> usize {
        self.cin * self.ksize * self.ksize
    }
}

/// A per-layer BatchNorm affine, shared with the engine.
#[derive(Clone)]
struct Bn {
    a: Arc<Vec<f32>>,
    b: Arc<Vec<f32>>,
}

/// One lowered instruction.  Buffer roles are fixed by the executor:
/// float activations ping-pong between two buffers, column/packed/gemm
/// scratch each have a single home.
enum Op {
    /// Float activation -> float column matrix [b*oh*ow, k] (optionally
    /// signed) in the column scratch.
    Im2col { g: ConvGeom, sign: bool },
    /// Float activation -> packed column bits, folding the PREVIOUS
    /// layer's bn affine into the sign when present (xnor arm).
    Encode { g: ConvGeom, bn: Option<Bn> },
    /// Float gemm over the column scratch + col2im into the other
    /// activation buffer (`alpha`: per-output-channel scale folded
    /// into the col2im pass — α-scheme layers on the float arms).
    ConvGemmF {
        w: Arc<Vec<f32>>,
        g: ConvGeom,
        imp: GemmImpl,
        alpha: Option<Arc<Vec<f32>>>,
    },
    /// Xnor gemm over the packed scratch + col2im into the other
    /// activation buffer (`alpha` as in [`Op::ConvGemmF`], folded into
    /// the i32 -> f32 col2im pass).
    ConvGemmX {
        w: Arc<PackedMatrix>,
        g: ConvGeom,
        imp: XnorImpl,
        alpha: Option<Arc<Vec<f32>>>,
    },
    /// Two-plane ternary gemm over the packed scratch (positive plane
    /// into the i32 gemm buffer, negative plane into its twin,
    /// combined in place) + col2im into the other activation buffer.
    ConvGemmT {
        pos: Arc<PackedMatrix>,
        neg: Arc<PackedMatrix>,
        g: ConvGeom,
        imp: XnorImpl,
    },
    /// 2x2 max-pool into the other activation buffer (input dims given).
    Pool { c: usize, h: usize, w: usize },
    /// In-place per-channel bn on the current activation (float arms,
    /// or a deferred xnor-arm bn materializing before a non-binarized
    /// consumer).
    BnConv { bn: Bn, c: usize, hw: usize },
    /// Flatten marker: the activation is henceforth rows [b, feat].
    /// Row-major NCHW already has (c, h, w) feature order — no data
    /// motion.
    Flatten { feat: usize },
    /// In-place sign over the current activation rows (float-arm fc
    /// input binarization; copies the network input into the ping
    /// buffer first when it is the direct source, e.g. fc-only nets).
    SignRows { k: usize },
    /// Float fc gemm: activation rows [b, k] (possibly the raw network
    /// input of an fc-only net) -> float gemm scratch [d, b].
    FcGemmF { w: Arc<Vec<f32>>, d: usize, k: usize, imp: GemmImpl },
    /// Xnor fc gemm: packed rows [b, k] -> i32 gemm scratch [d, b].
    FcGemmX { w: Arc<PackedMatrix>, d: usize, k: usize, imp: XnorImpl },
    /// Two-plane ternary fc gemm: packed rows [b, k] -> i32 gemm
    /// scratch [d, b] (negative plane via the twin scratch).
    FcGemmT {
        pos: Arc<PackedMatrix>,
        neg: Arc<PackedMatrix>,
        d: usize,
        k: usize,
        imp: XnorImpl,
    },
    /// Fused epilogue (xnor arm, image->binarized-fc boundary): float
    /// NCHW activation (+ optional deferred bn) -> packed rows
    /// [b, c*hw].  `bn: None` is the fc-only case: the raw input rows
    /// are sign-packed directly.
    SignPackImage { bn: Option<Bn>, c: usize, hw: usize },
    /// Fused epilogue (xnor arm, fc->binarized-fc boundary): gemm
    /// scratch [d, b] (`i32` from an xnor gemm, or `f32` from a
    /// non-binarized fc when `from_f32`) + optional α scale + bn ->
    /// packed rows [b, d].
    BnSignPackRows {
        bn: Bn,
        d: usize,
        from_f32: bool,
        alpha: Option<Arc<Vec<f32>>>,
    },
    /// i32 gemm scratch [d, b] + optional α scale + bn -> float rows
    /// [b, d]; into the logits tensor when `logits`, else into the
    /// other activation buffer (xnor arm: final layer, or a
    /// non-binarized consumer follows).
    BnRowsI {
        bn: Bn,
        d: usize,
        logits: bool,
        alpha: Option<Arc<Vec<f32>>>,
    },
    /// f32 gemm scratch [d, b] + optional α scale + bn -> float rows
    /// [b, d]; into the logits tensor when `logits`, else into the
    /// other activation buffer.
    BnRowsF {
        bn: Bn,
        d: usize,
        logits: bool,
        alpha: Option<Arc<Vec<f32>>>,
    },
}

/// Buffer sizes (elements / u32 words) required at `max_batch`.
#[derive(Debug, Clone, Copy, Default)]
struct BufSpec {
    act: usize,
    cols: usize,
    packed_words: usize,
    gemm_i32: usize,
    /// Twin i32 gemm scratch for the negative plane of ternary ops
    /// (zero on every other scheme — the buffer is not allocated).
    gemm_i32b: usize,
    gemm_f32: usize,
}

pub(crate) struct PlanInner {
    kernel: EngineKernel,
    scheme: QuantScheme,
    max_batch: usize,
    input_c: usize,
    input_h: usize,
    input_w: usize,
    classes: usize,
    labels: Option<Arc<Vec<String>>>,
    ops: Vec<Op>,
    names: Vec<String>,
    bufs: BufSpec,
    /// Persistent workers for `Threaded` xnor ops (present iff any op
    /// resolved to one).  Owned by the plan, shared by every session
    /// derived from it: steady-state serving never spawns a thread.
    pool: Option<Arc<ThreadPool>>,
}

/// A compiled, immutable execution plan for one (kernel, max_batch)
/// pair.  Cheap to clone; create per-thread [`Session`]s from it.
#[derive(Clone)]
pub struct Plan {
    inner: Arc<PlanInner>,
}

impl Plan {
    /// The kernel arm this plan was compiled for.
    pub fn kernel(&self) -> EngineKernel {
        self.inner.kernel
    }

    /// The quantization scheme the source spec declared (serving
    /// surfaces it in `/models` descriptors via `scheme().name()`).
    pub fn scheme(&self) -> QuantScheme {
        self.inner.scheme
    }

    /// Largest batch any session of this plan accepts (buffers are
    /// sized for it).
    pub fn max_batch(&self) -> usize {
        self.inner.max_batch
    }

    /// Per-image input shape (C, H, W) the plan expects.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        (self.inner.input_c, self.inner.input_h, self.inner.input_w)
    }

    /// Output class count (logits are [B, classes]).
    pub fn classes(&self) -> usize {
        self.inner.classes
    }

    /// Class-label table from the weight file, when it carried one
    /// (`labels()[c]` names class `c`) — flows through
    /// `coordinator::Backend::labels` to the HTTP reply schema.
    pub fn labels(&self) -> Option<&[String]> {
        self.inner.labels.as_ref().map(|l| &l[..])
    }

    /// Number of lowered ops (one profiling stage each).
    pub fn num_ops(&self) -> usize {
        self.inner.ops.len()
    }

    /// Stage names in execution order (`conv2:encode`,
    /// `fc1:bn_sign_pack`, ...).
    pub fn stage_names(&self) -> &[String] {
        &self.inner.names
    }

    /// Resolved xnor implementation per xnor-gemm op, in execution
    /// order (empty on the float arms) — how `forward_profiled` and the
    /// profile bench report which kernel actually ran.
    pub fn xnor_impls(&self) -> Vec<XnorImpl> {
        self.inner
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::ConvGemmX { imp, .. }
                | Op::FcGemmX { imp, .. }
                | Op::ConvGemmT { imp, .. }
                | Op::FcGemmT { imp, .. } => Some(*imp),
                _ => None,
            })
            .collect()
    }

    /// Per-buffer sizes a [`Session`] of this plan preallocates, as
    /// `(name, element-or-word count, bytes)` — the `describe` CLI's
    /// session-footprint table.  All entries are 4-byte elements
    /// (f32 / i32 / u32 words).
    pub fn buffer_sizes(&self) -> Vec<(&'static str, usize, usize)> {
        let s = self.inner.bufs;
        let out = self.inner.max_batch * self.inner.classes;
        [
            ("act_a (f32)", s.act),
            ("act_b (f32)", s.act),
            ("cols (f32)", s.cols),
            ("packed (u32 words)", s.packed_words),
            ("gemm_i32", s.gemm_i32),
            ("gemm_i32b", s.gemm_i32b),
            ("gemm_f32", s.gemm_f32),
            ("logits (f32)", out),
        ]
        .into_iter()
        .map(|(n, e)| (n, e, e * 4))
        .collect()
    }

    /// Materialize an execution context: every buffer the op program
    /// needs, preallocated for `max_batch`.  `Session::run` then never
    /// allocates.
    pub fn session(&self) -> Session {
        let s = self.inner.bufs;
        Session {
            plan: Arc::clone(&self.inner),
            act_a: vec![0.0; s.act],
            act_b: vec![0.0; s.act],
            cols: vec![0.0; s.cols],
            packed: PackedMatrix::with_word_capacity(s.packed_words),
            gemm_i32: vec![0; s.gemm_i32],
            gemm_i32b: vec![0; s.gemm_i32b],
            gemm_f32: vec![0.0; s.gemm_f32],
            out: Tensor::zeros(vec![
                self.inner.max_batch,
                self.inner.classes,
            ]),
        }
    }
}

impl BnnEngine {
    /// Lower the network into a flat op program for `kernel`, sized for
    /// batches up to `max_batch`.  All per-layer kernel dispatch happens
    /// here, once; [`Session::run`] just walks the ops.  The only
    /// fallible input is `max_batch` (the spec itself was validated at
    /// engine construction), surfaced as a typed [`SpecError`].
    ///
    /// A `Plan` is an `Arc` around the compiled program: `Clone` is a
    /// refcount bump, and the plan is `Send + Sync`, so a replica pool
    /// shares ONE plan and mints one [`Session`] per worker thread
    /// (compile once, N buffer sets — see
    /// `coordinator::NativeBackend::from_plan`).
    ///
    /// ```
    /// use bitkernel::bitops::XnorImpl;
    /// use bitkernel::model::EngineKernel;
    /// use bitkernel::tensor::Tensor;
    ///
    /// // Synthetic weights: no artifacts needed.
    /// let engine = bitkernel::testing::synthetic_engine(
    ///     [8, 8, 8, 8, 8, 8, 16, 16, 10], 7);
    ///
    /// // 1. compile once ...
    /// let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4)?;
    /// // 2. ... mint a session (preallocated buffers) ...
    /// let mut session = plan.session();
    /// // 3. ... serve: zero steady-state allocation.
    /// let images = Tensor::zeros(vec![2, 3, 32, 32]);
    /// let logits = session.run(&images);
    /// assert_eq!(logits.shape(), &[2, 10]);
    /// # Ok::<(), bitkernel::model::SpecError>(())
    /// ```
    pub fn plan(&self, kernel: EngineKernel, max_batch: usize)
                -> Result<Plan, SpecError> {
        if max_batch == 0 {
            return Err(SpecError::ZeroBatch);
        }
        let mb = max_batch;
        let mut ops: Vec<Op> = Vec::new();
        let mut names: Vec<String> = Vec::new();
        let mut bufs = BufSpec::default();

        let is_xnor = matches!(kernel, EngineKernel::Xnor(_));
        let scheme = self.spec.scheme();
        // Real-activation schemes never sign activations: every layer
        // lowers down the float arm even under `Xnor` kernels (the
        // binarized weights are already ±1 floats in the file).
        let signs = scheme.signs_activations();
        // Float gemm used wherever a float conv/fc runs: non-binarized
        // layers on every arm, everything on the Control/Optimized
        // arms.  Control is the paper's naive baseline; the other arms
        // get the widest float kernel (shared with `forward_reference`
        // so the compiled path stays bit-identical to the oracle).
        let float_imp = kernel.float_impl();
        // Largest thread count any resolved op asks for; > 0 means the
        // plan owns a persistent pool.
        let mut pool_threads = 0usize;

        let (ic, ih, iw) = self.spec.input();
        let (mut c, mut h, mut w) = (ic, ih, iw);
        // Xnor arm: each layer's bn is deferred and folded into its
        // consumer's sign — or materialized late (`BnConv`) when the
        // consumer is not binarized.  The owner name rides along for
        // the stage label.
        let mut pending_bn: Option<(Bn, String)> = None;

        for (li, layer) in self.convs.iter().enumerate() {
            let p = &layer.params;
            debug_assert_eq!(c, p.cin, "conv{} input channels", li + 1);
            let (oh, ow) = out_hw(h, w, p.ksize, p.ksize, p.stride, p.pad);
            let g = ConvGeom {
                cin: p.cin,
                cout: p.cout,
                ksize: p.ksize,
                stride: p.stride,
                pad: p.pad,
                h,
                w,
                oh,
                ow,
            };
            let n = mb * oh * ow;
            let k = g.k();
            let lname = format!("conv{}", li + 1);

            if is_xnor && layer.binarized && signs {
                let EngineKernel::Xnor(imp) = kernel else { unreachable!() };
                bufs.packed_words =
                    bufs.packed_words.max(n * k.div_ceil(32));
                ops.push(Op::Encode {
                    g,
                    bn: pending_bn.take().map(|(bn, _)| bn),
                });
                names.push(format!("{lname}:encode"));
                bufs.gemm_i32 = bufs.gemm_i32.max(p.cout * n);
                bufs.act = bufs.act.max(mb * p.cout * oh * ow);
                let rimp = plan_xnor_impl(imp, p.cout, k, n);
                if let XnorImpl::Threaded(t) = rimp {
                    pool_threads = pool_threads.max(t);
                }
                match &layer.w_packed_neg {
                    Some(neg) => {
                        bufs.gemm_i32b = bufs.gemm_i32b.max(p.cout * n);
                        ops.push(Op::ConvGemmT {
                            pos: Arc::clone(
                                layer
                                    .w_packed
                                    .as_ref()
                                    .expect("packed weights"),
                            ),
                            neg: Arc::clone(neg),
                            g,
                            imp: rimp,
                        });
                        names.push(ternary_gemm_stage_name(
                            &lname, imp, rimp,
                        ));
                    }
                    None => {
                        ops.push(Op::ConvGemmX {
                            w: Arc::clone(
                                layer
                                    .w_packed
                                    .as_ref()
                                    .expect("packed weights"),
                            ),
                            g,
                            imp: rimp,
                            alpha: layer.alpha.clone(),
                        });
                        names.push(xnor_gemm_stage_name(
                            &lname, imp, rimp,
                        ));
                    }
                }
            } else {
                // Float path: every conv on the float arms, and
                // non-binarized convs on the xnor arm — where a
                // deferred bn must materialize first (a binarized
                // consumer would have folded it into its sign).
                if let Some((bn, owner)) = pending_bn.take() {
                    ops.push(Op::BnConv { bn, c, hw: h * w });
                    names.push(format!("{owner}:bn"));
                }
                let imp = float_imp;
                bufs.cols = bufs.cols.max(n * k);
                let sign = layer.binarized && signs;
                ops.push(Op::Im2col { g, sign });
                names.push(if sign {
                    format!("{lname}:im2col+sign")
                } else {
                    format!("{lname}:im2col")
                });
                bufs.gemm_f32 = bufs.gemm_f32.max(p.cout * n);
                bufs.act = bufs.act.max(mb * p.cout * oh * ow);
                ops.push(Op::ConvGemmF {
                    w: Arc::clone(&layer.w_float),
                    g,
                    imp,
                    alpha: layer.alpha.clone(),
                });
                names.push(format!("{lname}:gemm"));
            }
            (c, h, w) = (p.cout, oh, ow);
            if layer.pool {
                ops.push(Op::Pool { c, h, w });
                names.push(format!("pool{}", li + 1));
                h /= 2;
                w /= 2;
            }
            // The layer's BatchNorm (applied AFTER pooling, as in the
            // reference pipeline): materialized on the float arms,
            // deferred into the next consumer on the xnor arm.
            let bn = Bn {
                a: Arc::clone(&layer.bn_a),
                b: Arc::clone(&layer.bn_b),
            };
            if is_xnor {
                pending_bn = Some((bn, lname));
            } else {
                ops.push(Op::BnConv { bn, c, hw: h * w });
                names.push(format!("{lname}:bn"));
            }
        }

        let feat = c * h * w;
        debug_assert!(!self.fcs.is_empty(), "validated spec has fcs");
        let first_fc_binarized =
            self.fcs.first().is_some_and(|f| f.binarized);
        if is_xnor && first_fc_binarized && signs {
            // The flatten boundary feeds a binarized fc: emit its
            // packed rows directly.  With convs the last conv's bn is
            // pending and folds into the sign; without (fc-only nets)
            // the raw input rows are sign-packed as-is.
            bufs.packed_words =
                bufs.packed_words.max(mb * feat.div_ceil(32));
            let bn = pending_bn.take().map(|(bn, _)| bn);
            let fused_bn = bn.is_some();
            ops.push(Op::SignPackImage { bn, c, hw: h * w });
            names.push(if fused_bn {
                "flatten:bn_sign_pack".to_string()
            } else {
                "flatten:sign_pack".to_string()
            });
        } else {
            if let Some((bn, owner)) = pending_bn.take() {
                // Xnor arm, but the first fc is not binarized: the
                // deferred conv bn materializes.
                ops.push(Op::BnConv { bn, c, hw: h * w });
                names.push(format!("{owner}:bn"));
            }
            ops.push(Op::Flatten { feat });
            names.push("flatten".to_string());
        }

        let mut kdim = feat;
        let nf = self.fcs.len();
        for (fi, fc) in self.fcs.iter().enumerate() {
            debug_assert_eq!(kdim, fc.din, "fc{} input width", fi + 1);
            let lname = format!("fc{}", fi + 1);
            let last = fi + 1 == nf;
            // Does the next consumer want packed sign rows?
            let next_binarized =
                !last && is_xnor && self.fcs[fi + 1].binarized && signs;
            let bn = Bn {
                a: Arc::clone(&fc.bn_a),
                b: Arc::clone(&fc.bn_b),
            };
            if is_xnor && fc.binarized && signs {
                let EngineKernel::Xnor(imp) = kernel else { unreachable!() };
                bufs.gemm_i32 = bufs.gemm_i32.max(fc.dout * mb);
                let rimp = plan_xnor_impl(imp, fc.dout, fc.din, mb);
                if let XnorImpl::Threaded(t) = rimp {
                    pool_threads = pool_threads.max(t);
                }
                match &fc.w_packed_neg {
                    Some(neg) => {
                        bufs.gemm_i32b =
                            bufs.gemm_i32b.max(fc.dout * mb);
                        ops.push(Op::FcGemmT {
                            pos: Arc::clone(
                                fc.w_packed
                                    .as_ref()
                                    .expect("packed weights"),
                            ),
                            neg: Arc::clone(neg),
                            d: fc.dout,
                            k: fc.din,
                            imp: rimp,
                        });
                        names.push(ternary_gemm_stage_name(
                            &lname, imp, rimp,
                        ));
                    }
                    None => {
                        ops.push(Op::FcGemmX {
                            w: Arc::clone(
                                fc.w_packed
                                    .as_ref()
                                    .expect("packed weights"),
                            ),
                            d: fc.dout,
                            k: fc.din,
                            imp: rimp,
                        });
                        names.push(xnor_gemm_stage_name(
                            &lname, imp, rimp,
                        ));
                    }
                }
                if next_binarized {
                    bufs.packed_words = bufs
                        .packed_words
                        .max(mb * fc.dout.div_ceil(32));
                    let alpha = fc.alpha.clone();
                    let has_alpha = alpha.is_some();
                    ops.push(Op::BnSignPackRows {
                        bn,
                        d: fc.dout,
                        from_f32: false,
                        alpha,
                    });
                    names.push(bn_pack_stage_name(&lname, has_alpha));
                } else {
                    if !last {
                        bufs.act = bufs.act.max(mb * fc.dout);
                    }
                    let alpha = fc.alpha.clone();
                    let has_alpha = alpha.is_some();
                    ops.push(Op::BnRowsI {
                        bn,
                        d: fc.dout,
                        logits: last,
                        alpha,
                    });
                    names.push(bn_rows_stage_name(
                        &lname, has_alpha, last,
                    ));
                }
            } else {
                // Float-gemm fc: every fc on the float arms, and
                // non-binarized fcs on the xnor arm (real-valued input
                // rows, no sign).
                if !is_xnor && fc.binarized && signs {
                    bufs.act = bufs.act.max(mb * fc.din);
                    ops.push(Op::SignRows { k: fc.din });
                    names.push(format!("{lname}:sign"));
                }
                bufs.gemm_f32 = bufs.gemm_f32.max(fc.dout * mb);
                ops.push(Op::FcGemmF {
                    w: Arc::clone(&fc.w_float),
                    d: fc.dout,
                    k: fc.din,
                    imp: float_imp,
                });
                names.push(format!("{lname}:gemm"));
                if next_binarized {
                    bufs.packed_words = bufs
                        .packed_words
                        .max(mb * fc.dout.div_ceil(32));
                    let alpha = fc.alpha.clone();
                    let has_alpha = alpha.is_some();
                    ops.push(Op::BnSignPackRows {
                        bn,
                        d: fc.dout,
                        from_f32: true,
                        alpha,
                    });
                    names.push(bn_pack_stage_name(&lname, has_alpha));
                } else {
                    if !last {
                        bufs.act = bufs.act.max(mb * fc.dout);
                    }
                    let alpha = fc.alpha.clone();
                    let has_alpha = alpha.is_some();
                    ops.push(Op::BnRowsF {
                        bn,
                        d: fc.dout,
                        logits: last,
                        alpha,
                    });
                    names.push(bn_rows_stage_name(
                        &lname, has_alpha, last,
                    ));
                }
            }
            kdim = fc.dout;
        }
        debug_assert_eq!(kdim, self.spec.classes(), "final fc width");

        Ok(Plan {
            inner: Arc::new(PlanInner {
                kernel,
                scheme,
                max_batch,
                input_c: ic,
                input_h: ih,
                input_w: iw,
                classes: self.spec.classes(),
                labels: self.labels.clone(),
                ops,
                names,
                bufs,
                pool: (pool_threads > 0)
                    .then(|| Arc::new(ThreadPool::new(pool_threads))),
            }),
        })
    }
}

/// Opt-in microbench calibration for plan-time `Auto` resolution
/// (`BITKERNEL_CALIBRATE=1`; costs a few ms per distinct op shape).
fn calibrate_enabled() -> bool {
    std::env::var_os("BITKERNEL_CALIBRATE").is_some_and(|v| v != "0")
}

/// Resolve one op's xnor impl at plan time: `Auto` goes through the
/// shape heuristic (or, when calibration is enabled, the persistent
/// [`calibration cache`](super::calib) — which microbenches each
/// distinct shape at most once per hardware/impl-set and then answers
/// from memory or the sidecar file, so registry reloads and LRU
/// rebuilds stop paying it); explicit impls pass through untouched.
fn plan_xnor_impl(imp: XnorImpl, d: usize, k: usize, n: usize)
                  -> XnorImpl {
    if imp == XnorImpl::Auto && calibrate_enabled() {
        super::calib::global().resolve(d, k, n)
    } else {
        imp.resolve(d, k, n)
    }
}

/// Stage name for an xnor-gemm op.  When the arm is `Auto` the chosen
/// impl is recorded in the name (`conv2:xnor-gemm[threaded8]`), so
/// `run_profiled` and the profile bench report which kernel ran;
/// explicit arms keep the stable bare name.
fn xnor_gemm_stage_name(lname: &str, requested: XnorImpl,
                        resolved: XnorImpl) -> String {
    if requested == XnorImpl::Auto {
        format!("{lname}:xnor-gemm[{}]", resolved.name())
    } else {
        format!("{lname}:xnor-gemm")
    }
}

/// Stage name for a two-plane ternary gemm op; like
/// [`xnor_gemm_stage_name`], `Auto` records the resolved impl.
fn ternary_gemm_stage_name(lname: &str, requested: XnorImpl,
                           resolved: XnorImpl) -> String {
    if requested == XnorImpl::Auto {
        format!("{lname}:ternary-gemm[{}]", resolved.name())
    } else {
        format!("{lname}:ternary-gemm")
    }
}

/// Stage name for a fused bn+sign+pack epilogue, prefixed with `alpha_`
/// when a per-channel α scale is folded in.
fn bn_pack_stage_name(lname: &str, alpha: bool) -> String {
    if alpha {
        format!("{lname}:alpha_bn_sign_pack")
    } else {
        format!("{lname}:bn_sign_pack")
    }
}

/// Stage name for a bn-rows epilogue (optionally α-scaled, optionally
/// writing the logits tensor).
fn bn_rows_stage_name(lname: &str, alpha: bool, last: bool) -> String {
    match (alpha, last) {
        (true, true) => format!("{lname}:alpha+bn+logits"),
        (true, false) => format!("{lname}:alpha+bn"),
        (false, true) => format!("{lname}:bn+logits"),
        (false, false) => format!("{lname}:bn"),
    }
}

/// Which buffer holds the current float activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cur {
    /// The caller's input images (read-only; consumed by the first
    /// float-reading op without cloning).
    Input,
    A,
    B,
}

/// An execution context over one [`Plan`]: the plan's op program plus
/// every buffer it needs, preallocated for `max_batch`.  One session
/// serves one thread; `run` reuses all buffers, so steady-state
/// inference performs no heap allocation.
pub struct Session {
    plan: Arc<PlanInner>,
    /// Ping-pong float NCHW / row activations.
    act_a: Vec<f32>,
    act_b: Vec<f32>,
    /// Float im2col scratch.
    cols: Vec<f32>,
    /// Packed activation bits (im2col columns / fc rows).
    packed: PackedMatrix,
    /// Gemm outputs, [D, N] row-major.
    gemm_i32: Vec<i32>,
    /// Negative-plane scratch for ternary gemms (empty otherwise).
    gemm_i32b: Vec<i32>,
    gemm_f32: Vec<f32>,
    /// Logits [b, classes]; returned by reference from `run`.
    out: Tensor,
}

impl Session {
    /// The kernel arm of the plan this session executes.
    pub fn kernel(&self) -> EngineKernel {
        self.plan.kernel
    }

    /// Largest batch `run` accepts.
    pub fn max_batch(&self) -> usize {
        self.plan.max_batch
    }

    fn check_images(&self, images: &Tensor) -> usize {
        assert_eq!(images.shape().len(), 4, "expected NCHW images");
        assert_eq!(images.dim(1), self.plan.input_c, "image channels");
        assert_eq!(images.dim(2), self.plan.input_h, "image height");
        assert_eq!(images.dim(3), self.plan.input_w, "image width");
        images.dim(0)
    }

    /// Run inference on `images` ([B, C, H, W] normalized, matching the
    /// plan's input shape, B <= `max_batch`); returns the logits
    /// [B, classes] by reference into the session's output buffer
    /// (valid until the next `run`).
    pub fn run(&mut self, images: &Tensor) -> &Tensor {
        let b = self.check_images(images);
        self.run_inner(images.data(), b, false);
        &self.out
    }

    /// [`Session::run`] over a borrowed raw image slice
    /// (`data.len() == b * C*H*W`) — the batch-view path `evaluate`
    /// uses to step through a dataset tensor without copying slices.
    pub fn run_images(&mut self, data: &[f32], b: usize) -> &Tensor {
        self.run_inner(data, b, false);
        &self.out
    }

    /// [`Session::run`] with a per-op wall-time breakdown
    /// `(stage_name, seconds)` (the profiling path of
    /// `cargo bench --bench profile`).
    pub fn run_profiled(&mut self, images: &Tensor)
                        -> (&Tensor, Vec<(String, f64)>) {
        let b = self.check_images(images);
        let stages = self.run_inner(images.data(), b, true);
        (&self.out, stages)
    }

    /// (pointer, capacity) of every internal buffer — the allocation
    /// fingerprint `tests/plan_session.rs` uses to prove steady-state
    /// runs never reallocate.
    pub fn buffer_signature(&self) -> Vec<(usize, usize)> {
        vec![
            (self.act_a.as_ptr() as usize, self.act_a.capacity()),
            (self.act_b.as_ptr() as usize, self.act_b.capacity()),
            (self.cols.as_ptr() as usize, self.cols.capacity()),
            (self.packed.data.as_ptr() as usize, self.packed.word_capacity()),
            (self.gemm_i32.as_ptr() as usize, self.gemm_i32.capacity()),
            (self.gemm_i32b.as_ptr() as usize, self.gemm_i32b.capacity()),
            (self.gemm_f32.as_ptr() as usize, self.gemm_f32.capacity()),
            (self.out.data().as_ptr() as usize, self.out.capacity()),
        ]
    }

    fn run_inner(&mut self, x: &[f32], b: usize, profile: bool)
                 -> Vec<(String, f64)> {
        let plan = Arc::clone(&self.plan);
        assert!(b >= 1, "empty batch");
        assert!(b <= plan.max_batch,
                "batch {b} exceeds plan max_batch {}", plan.max_batch);
        let chw = plan.input_c * plan.input_h * plan.input_w;
        assert_eq!(x.len(), b * chw, "image data length");

        let mut stages: Vec<(String, f64)> = Vec::new();
        let mut cur = Cur::Input;
        for (op, name) in plan.ops.iter().zip(&plan.names) {
            // Only the profiled path pays for the clock reads.
            let sw = profile.then(Stopwatch::start);
            match op {
                Op::Im2col { g, sign } => {
                    let n = b * g.oh * g.ow;
                    let k = g.k();
                    let src: &[f32] = match cur {
                        Cur::Input => x,
                        Cur::A => &self.act_a[..],
                        Cur::B => &self.act_b[..],
                    };
                    let cols = &mut self.cols[..n * k];
                    im2col_t_into(&src[..b * g.cin * g.h * g.w], b, g.cin,
                                  g.h, g.w, g.ksize, g.ksize, g.stride,
                                  g.pad, cols);
                    if *sign {
                        sign_inplace(cols);
                    }
                }
                Op::Encode { g, bn } => {
                    let n = b * g.oh * g.ow;
                    let src: &[f32] = match cur {
                        Cur::Input => x,
                        Cur::A => &self.act_a[..],
                        Cur::B => &self.act_b[..],
                    };
                    self.packed.reset(n, g.k());
                    let bn_ref =
                        bn.as_ref().map(|bn| (&bn.a[..], &bn.b[..]));
                    im2col_pack_bn(&src[..b * g.cin * g.h * g.w], b, g.cin,
                                   g.h, g.w, g.ksize, g.ksize, g.stride,
                                   g.pad, bn_ref, &mut self.packed);
                }
                Op::ConvGemmF { w, g, imp, alpha } => {
                    let n = b * g.oh * g.ow;
                    let (d, k) = (g.cout, g.k());
                    gemm_f32(w, &self.cols[..n * k],
                             &mut self.gemm_f32[..d * n], d, k, n, *imp);
                    let (dst, next) = match cur {
                        Cur::A => (&mut self.act_b, Cur::B),
                        _ => (&mut self.act_a, Cur::A),
                    };
                    match alpha {
                        Some(al) => alpha_col2im_nchw(
                            &self.gemm_f32[..d * n], b, d, g.oh, g.ow,
                            al, &mut dst[..d * n],
                        ),
                        None => col2im_nchw_into(
                            &self.gemm_f32[..d * n], b, d, g.oh, g.ow,
                            &mut dst[..d * n],
                        ),
                    }
                    cur = next;
                }
                Op::ConvGemmX { w, g, imp, alpha } => {
                    let n = b * g.oh * g.ow;
                    let d = g.cout;
                    match plan.pool.as_deref() {
                        Some(pool) => xnor_gemm_pooled(
                            w, &self.packed,
                            &mut self.gemm_i32[..d * n], *imp, pool,
                        ),
                        None => xnor_gemm(w, &self.packed,
                                          &mut self.gemm_i32[..d * n],
                                          *imp),
                    }
                    let (dst, next) = match cur {
                        Cur::A => (&mut self.act_b, Cur::B),
                        _ => (&mut self.act_a, Cur::A),
                    };
                    match alpha {
                        Some(al) => alpha_col2im_nchw_i32(
                            &self.gemm_i32[..d * n], b, d, g.oh, g.ow,
                            al, &mut dst[..d * n],
                        ),
                        None => col2im_nchw_i32_into(
                            &self.gemm_i32[..d * n], b, d, g.oh, g.ow,
                            &mut dst[..d * n],
                        ),
                    }
                    cur = next;
                }
                Op::ConvGemmT { pos, neg, g, imp } => {
                    let n = b * g.oh * g.ow;
                    let d = g.cout;
                    match plan.pool.as_deref() {
                        Some(pool) => ternary_gemm_pooled(
                            pos, neg, &self.packed,
                            &mut self.gemm_i32[..d * n],
                            &mut self.gemm_i32b[..d * n], *imp, pool,
                        ),
                        None => ternary_gemm(
                            pos, neg, &self.packed,
                            &mut self.gemm_i32[..d * n],
                            &mut self.gemm_i32b[..d * n], *imp,
                        ),
                    }
                    let (dst, next) = match cur {
                        Cur::A => (&mut self.act_b, Cur::B),
                        _ => (&mut self.act_a, Cur::A),
                    };
                    col2im_nchw_i32_into(&self.gemm_i32[..d * n], b, d,
                                         g.oh, g.ow, &mut dst[..d * n]);
                    cur = next;
                }
                Op::Pool { c, h, w } => {
                    let (c, h, w) = (*c, *h, *w);
                    let (src, dst, next) = match cur {
                        Cur::A => (&self.act_a[..], &mut self.act_b, Cur::B),
                        Cur::B => (&self.act_b[..], &mut self.act_a, Cur::A),
                        Cur::Input => unreachable!("pool reads activations"),
                    };
                    maxpool2_into(&src[..b * c * h * w], b * c, h, w,
                                  &mut dst[..b * c * (h / 2) * (w / 2)]);
                    cur = next;
                }
                Op::BnConv { bn, c, hw } => {
                    let act = match cur {
                        Cur::A => &mut self.act_a,
                        Cur::B => &mut self.act_b,
                        Cur::Input => unreachable!("bn reads activations"),
                    };
                    bn_affine_nchw_slice(&mut act[..b * c * hw], b, *c,
                                         *hw, &bn.a[..], &bn.b[..]);
                }
                Op::Flatten { feat } => {
                    // Row-major NCHW is already (c, h, w) feature order;
                    // purely a logical reinterpretation.  `cur` may
                    // still be the raw input (fc-only nets).
                    debug_assert!(matches!(cur, Cur::Input)
                                  || b * feat <= self.act_a.len());
                }
                Op::SignRows { k } => {
                    let k = *k;
                    if matches!(cur, Cur::Input) {
                        // fc-only net: the raw input rows must land in
                        // a mutable buffer before signing in place.
                        self.act_a[..b * k].copy_from_slice(&x[..b * k]);
                        cur = Cur::A;
                    }
                    let act = match cur {
                        Cur::A => &mut self.act_a,
                        Cur::B => &mut self.act_b,
                        Cur::Input => unreachable!("handled above"),
                    };
                    sign_inplace(&mut act[..b * k]);
                }
                Op::FcGemmF { w, d, k, imp } => {
                    let (d, k) = (*d, *k);
                    let src: &[f32] = match cur {
                        Cur::Input => x,
                        Cur::A => &self.act_a[..],
                        Cur::B => &self.act_b[..],
                    };
                    gemm_f32(w, &src[..b * k],
                             &mut self.gemm_f32[..d * b], d, k, b, *imp);
                }
                Op::FcGemmX { w, d, k, imp } => {
                    let d = *d;
                    debug_assert_eq!(self.packed.rows, b);
                    debug_assert_eq!(self.packed.k, *k);
                    match plan.pool.as_deref() {
                        Some(pool) => xnor_gemm_pooled(
                            w, &self.packed,
                            &mut self.gemm_i32[..d * b], *imp, pool,
                        ),
                        None => xnor_gemm(w, &self.packed,
                                          &mut self.gemm_i32[..d * b],
                                          *imp),
                    }
                }
                Op::FcGemmT { pos, neg, d, k, imp } => {
                    let d = *d;
                    debug_assert_eq!(self.packed.rows, b);
                    debug_assert_eq!(self.packed.k, *k);
                    match plan.pool.as_deref() {
                        Some(pool) => ternary_gemm_pooled(
                            pos, neg, &self.packed,
                            &mut self.gemm_i32[..d * b],
                            &mut self.gemm_i32b[..d * b], *imp, pool,
                        ),
                        None => ternary_gemm(
                            pos, neg, &self.packed,
                            &mut self.gemm_i32[..d * b],
                            &mut self.gemm_i32b[..d * b], *imp,
                        ),
                    }
                }
                Op::SignPackImage { bn, c, hw } => {
                    let (c, hw) = (*c, *hw);
                    let src: &[f32] = match cur {
                        Cur::Input => x,
                        Cur::A => &self.act_a[..],
                        Cur::B => &self.act_b[..],
                    };
                    self.packed.reset(b, c * hw);
                    match bn {
                        Some(bn) => bn_sign_pack_nchw(
                            &src[..b * c * hw], b, c, hw, &bn.a[..],
                            &bn.b[..], &mut self.packed,
                        ),
                        None => pack_rows_from(&src[..b * c * hw],
                                               &mut self.packed),
                    }
                }
                Op::BnSignPackRows { bn, d, from_f32, alpha } => {
                    let d = *d;
                    self.packed.reset(b, d);
                    match (*from_f32, alpha) {
                        (true, Some(al)) => bn_sign_pack_rows_f32_alpha(
                            &self.gemm_f32[..d * b], d, b, al, &bn.a[..],
                            &bn.b[..], &mut self.packed,
                        ),
                        (true, None) => bn_sign_pack_rows_f32(
                            &self.gemm_f32[..d * b], d, b, &bn.a[..],
                            &bn.b[..], &mut self.packed,
                        ),
                        (false, Some(al)) => bn_sign_pack_rows_i32_alpha(
                            &self.gemm_i32[..d * b], d, b, al, &bn.a[..],
                            &bn.b[..], &mut self.packed,
                        ),
                        (false, None) => bn_sign_pack_rows_i32(
                            &self.gemm_i32[..d * b], d, b, &bn.a[..],
                            &bn.b[..], &mut self.packed,
                        ),
                    }
                }
                Op::BnRowsI { bn, d, logits, alpha } => {
                    let d = *d;
                    let dst: &mut [f32] = if *logits {
                        self.out.reset(&[b, d]);
                        self.out.data_mut()
                    } else {
                        let (dst, next) = match cur {
                            Cur::A => (&mut self.act_b, Cur::B),
                            _ => (&mut self.act_a, Cur::A),
                        };
                        cur = next;
                        &mut dst[..b * d]
                    };
                    match alpha {
                        Some(al) => bn_rows_from_gemm_i32_alpha(
                            &self.gemm_i32[..d * b], d, b, al, &bn.a[..],
                            &bn.b[..], dst,
                        ),
                        None => bn_rows_from_gemm_i32(
                            &self.gemm_i32[..d * b], d, b, &bn.a[..],
                            &bn.b[..], dst,
                        ),
                    }
                }
                Op::BnRowsF { bn, d, logits, alpha } => {
                    let d = *d;
                    let dst: &mut [f32] = if *logits {
                        self.out.reset(&[b, d]);
                        self.out.data_mut()
                    } else {
                        let (dst, next) = match cur {
                            Cur::A => (&mut self.act_b, Cur::B),
                            _ => (&mut self.act_a, Cur::A),
                        };
                        cur = next;
                        &mut dst[..b * d]
                    };
                    match alpha {
                        Some(al) => bn_rows_from_gemm_f32_alpha(
                            &self.gemm_f32[..d * b], d, b, al, &bn.a[..],
                            &bn.b[..], dst,
                        ),
                        None => bn_rows_from_gemm_f32(
                            &self.gemm_f32[..d * b], d, b, &bn.a[..],
                            &bn.b[..], dst,
                        ),
                    }
                }
            }
            if let Some(sw) = sw {
                stages.push((name.clone(), sw.elapsed_secs()));
            }
        }
        debug_assert_eq!(self.out.shape(), &[b, plan.classes]);
        stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The replica pool shares one `Plan` across worker threads and
    /// moves each minted `Session` into its own thread — pin the auto
    /// traits that make that legal (a regression here would break
    /// `coordinator::Router` at its call sites, far from the cause).
    #[test]
    fn plan_is_shareable_and_sessions_are_movable() {
        fn send_sync<T: Send + Sync>() {}
        fn send<T: Send>() {}
        send_sync::<Plan>();
        send::<Session>();
    }
}
